//! Small shared utilities: deterministic RNG, an offline property-testing
//! harness, a micro-benchmark kit, and table formatting.
//!
//! The build image is fully offline, so crates like `rand`, `proptest` and
//! `criterion` are unavailable; these modules provide the subset of their
//! functionality the rest of the crate needs, with deterministic seeding so
//! every test and benchmark is reproducible.

pub mod benchkit;
pub mod error;
pub mod parallel;
pub mod proptest_lite;
pub mod rng;
pub mod table;

pub use error::{Context, Error, Result};
pub use parallel::par_map;
pub use rng::SplitMix64;

/// Round `x` up to the next multiple of `to` (`to > 0`).
#[inline]
pub fn round_up(x: usize, to: usize) -> usize {
    debug_assert!(to > 0);
    x.div_ceil(to) * to
}

/// Ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Euclidean (always non-negative) remainder of `a mod m` for signed `a`.
///
/// GrateTile configurations (Eq. 1) are sets of residues of possibly
/// negative boundary offsets such as `-k`, so the euclidean remainder is
/// the right notion everywhere in `tiling`.
#[inline]
pub fn umod(a: i64, m: i64) -> i64 {
    debug_assert!(m > 0);
    a.rem_euclid(m)
}

/// Geometric mean of a slice of positive values; 0.0 for an empty slice.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.max(1e-12).ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basic() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
        assert_eq!(round_up(511, 16), 512);
    }

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(0, 8), 0);
        assert_eq!(ceil_div(1, 8), 1);
        assert_eq!(ceil_div(8, 8), 1);
        assert_eq!(ceil_div(9, 8), 2);
    }

    #[test]
    fn umod_negative_operands() {
        assert_eq!(umod(-1, 8), 7);
        assert_eq!(umod(-9, 8), 7);
        assert_eq!(umod(9, 8), 1);
        assert_eq!(umod(0, 8), 0);
        // AlexNet CONV1 example from the paper: -k = -5 (mod 32) = 27.
        assert_eq!(umod(-5, 32), 27);
    }

    #[test]
    fn geomean_matches_hand_computation() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
        let g3 = geomean(&[2.0, 2.0, 2.0]);
        assert!((g3 - 2.0).abs() < 1e-12);
    }
}
