//! Console table rendering for the evaluation harness.
//!
//! The harness prints the same rows the paper's tables/figures report;
//! this module keeps the formatting consistent (fixed-width columns,
//! optional markdown mode for pasting into EXPERIMENTS.md).

/// A simple column-aligned text table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Self { title: title.to_string(), ..Default::default() }
    }

    pub fn header<S: Into<String>>(mut self, cols: Vec<S>) -> Self {
        self.header = cols.into_iter().map(Into::into).collect();
        self
    }

    pub fn row<S: Into<String>>(&mut self, cols: Vec<S>) -> &mut Self {
        let row: Vec<String> = cols.into_iter().map(Into::into).collect();
        assert_eq!(
            row.len(),
            self.header.len(),
            "row width must match header width"
        );
        self.rows.push(row);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned plain-text table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let line = |cols: &[String], w: &[usize]| -> String {
            cols.iter()
                .zip(w)
                .map(|(c, &w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.header, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len().saturating_sub(1))));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &w));
            out.push('\n');
        }
        out
    }

    /// Render as GitHub-flavoured markdown.
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("**{}**\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }

    /// Render as CSV (no title).
    pub fn render_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }

    /// Write the CSV rendering to `results/<name>.csv` (best-effort).
    pub fn save_csv(&self, name: &str) {
        let _ = std::fs::create_dir_all("results");
        let _ = std::fs::write(format!("results/{name}.csv"), self.render_csv());
    }
}

/// Format a fraction as a percentage with one decimal, e.g. `54.7`.
pub fn pct(x: f64) -> String {
    format!("{:.1}", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo").header(vec!["name", "value"]);
        t.row(vec!["short", "1"]);
        t.row(vec!["a-much-longer-name", "22"]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("a-much-longer-name"));
        // Both data rows end at a consistent column for "value".
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn mismatched_row_panics() {
        let mut t = Table::new("x").header(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn markdown_and_csv_shapes() {
        let mut t = Table::new("m").header(vec!["a", "b"]);
        t.row(vec!["1", "2"]);
        let md = t.render_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("|---|---|"));
        let csv = t.render_csv();
        assert_eq!(csv, "a,b\n1,2\n");
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(0.547), "54.7");
        assert_eq!(pct(0.0), "0.0");
    }
}
