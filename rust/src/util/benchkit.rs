//! Minimal benchmarking kit (offline stand-in for `criterion`).
//!
//! `cargo bench` targets in this crate use `harness = false` and drive
//! [`Bencher`] directly: warm-up, fixed-duration sampling, and a
//! median/mean/σ report with throughput. Deterministic workloads make the
//! numbers comparable across runs; results are also appended as CSV so
//! EXPERIMENTS.md §Perf can cite exact figures.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Result of one benchmark: timings in nanoseconds per iteration.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    /// Optional bytes processed per iteration, for GB/s reporting.
    pub bytes_per_iter: Option<u64>,
    /// Optional items processed per iteration, for item/s reporting.
    pub items_per_iter: Option<u64>,
}

impl Sample {
    pub fn throughput_gbs(&self) -> Option<f64> {
        self.bytes_per_iter
            .map(|b| b as f64 / self.median_ns)
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<44} {:>12.1} ns/iter (mean {:>12.1} ± {:>8.1}, n={})",
            self.name, self.median_ns, self.mean_ns, self.stddev_ns, self.iters
        );
        if let Some(gbs) = self.throughput_gbs() {
            s.push_str(&format!("  {gbs:>8.3} GB/s"));
        }
        if let Some(items) = self.items_per_iter {
            let per_s = items as f64 / (self.median_ns * 1e-9);
            s.push_str(&format!("  {per_s:>12.0} items/s"));
        }
        s
    }

    pub fn csv_row(&self) -> String {
        format!(
            "{},{},{:.1},{:.1},{:.1},{},{}",
            self.name,
            self.iters,
            self.median_ns,
            self.mean_ns,
            self.stddev_ns,
            self.bytes_per_iter.map(|b| b.to_string()).unwrap_or_default(),
            self.items_per_iter.map(|b| b.to_string()).unwrap_or_default(),
        )
    }
}

/// Fixed-budget micro-bench runner.
pub struct Bencher {
    warmup: Duration,
    measure: Duration,
    samples: Vec<Sample>,
    /// Quick mode (env `BENCH_QUICK=1`): tiny budgets for CI smoke runs.
    quick: bool,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
        let (warmup, measure) = if quick {
            (Duration::from_millis(20), Duration::from_millis(80))
        } else {
            (Duration::from_millis(200), Duration::from_millis(900))
        };
        Self { warmup, measure, samples: Vec::new(), quick }
    }

    pub fn is_quick(&self) -> bool {
        self.quick
    }

    /// Benchmark `f`, labelling the result `name`.
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> &Sample {
        self.bench_with(name, None, None, &mut f)
    }

    /// Benchmark with a bytes-per-iteration annotation (GB/s reporting).
    pub fn bench_bytes<R>(
        &mut self,
        name: &str,
        bytes: u64,
        mut f: impl FnMut() -> R,
    ) -> &Sample {
        self.bench_with(name, Some(bytes), None, &mut f)
    }

    /// Benchmark with an items-per-iteration annotation.
    pub fn bench_items<R>(
        &mut self,
        name: &str,
        items: u64,
        mut f: impl FnMut() -> R,
    ) -> &Sample {
        self.bench_with(name, None, Some(items), &mut f)
    }

    fn bench_with<R>(
        &mut self,
        name: &str,
        bytes: Option<u64>,
        items: Option<u64>,
        f: &mut impl FnMut() -> R,
    ) -> &Sample {
        // Warm-up and per-iteration cost estimate.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup {
            black_box(f());
            warm_iters += 1;
        }
        let est_ns = (warm_start.elapsed().as_nanos() as f64 / warm_iters.max(1) as f64).max(1.0);

        // Aim for ~30 timed batches within the measurement budget.
        let batch = ((self.measure.as_nanos() as f64 / 30.0 / est_ns).ceil() as u64).max(1);
        let mut per_iter_ns: Vec<f64> = Vec::new();
        let meas_start = Instant::now();
        let mut total_iters = 0u64;
        while meas_start.elapsed() < self.measure || per_iter_ns.len() < 5 {
            let t = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            per_iter_ns.push(t.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
            if per_iter_ns.len() > 10_000 {
                break;
            }
        }

        per_iter_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = per_iter_ns[per_iter_ns.len() / 2];
        let mean = per_iter_ns.iter().sum::<f64>() / per_iter_ns.len() as f64;
        let var = per_iter_ns.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / per_iter_ns.len() as f64;

        let sample = Sample {
            name: name.to_string(),
            iters: total_iters,
            median_ns: median,
            mean_ns: mean,
            stddev_ns: var.sqrt(),
            bytes_per_iter: bytes,
            items_per_iter: items,
        };
        println!("{}", sample.report());
        self.samples.push(sample);
        self.samples.last().unwrap()
    }

    /// All samples collected so far.
    pub fn samples(&self) -> &[Sample] {
        &self.samples
    }

    /// Median-time speedup of sample `name` over sample `baseline`
    /// (> 1 means `name` is faster). `None` until both are recorded;
    /// the latest sample wins when a name was benched twice.
    pub fn speedup(&self, name: &str, baseline: &str) -> Option<f64> {
        let a = self.samples.iter().rev().find(|s| s.name == name)?;
        let b = self.samples.iter().rev().find(|s| s.name == baseline)?;
        Some(b.median_ns / a.median_ns)
    }

    /// Print and return the speedup of `name` over `baseline` — the
    /// perf benches use this for their headline vs-baseline lines.
    pub fn report_speedup(&self, name: &str, baseline: &str) -> Option<f64> {
        let s = self.speedup(name, baseline)?;
        println!("{name:<44} {s:>10.1}x faster than {baseline}");
        Some(s)
    }

    /// Write collected samples as a machine-readable JSON array
    /// (best-effort, overwrites): one object per sample with name,
    /// median/mean/σ, throughput annotations and the git revision —
    /// the `BENCH_PACK.json` / `BENCH_WALK.json` perf-trajectory
    /// artifacts CI uploads per commit. Hand-rolled JSON: the crate is
    /// dependency-free.
    pub fn write_json(&self, bench_name: &str, path: &str) {
        let rev = git_rev();
        let mut s = String::from("[\n");
        for (i, smp) in self.samples.iter().enumerate() {
            let gbs = smp
                .throughput_gbs()
                .map(|g| format!("{g:.4}"))
                .unwrap_or_else(|| "null".into());
            let items = smp
                .items_per_iter
                .map(|n| (n as f64 / (smp.median_ns * 1e-9)).round().to_string())
                .unwrap_or_else(|| "null".into());
            s.push_str(&format!(
                "  {{\"bench\": \"{}\", \"name\": \"{}\", \"iters\": {}, \
                 \"median_ns\": {:.1}, \"mean_ns\": {:.1}, \"stddev_ns\": {:.1}, \
                 \"throughput_gbs\": {}, \"items_per_s\": {}, \"git_rev\": \"{}\"}}{}\n",
                json_escape(bench_name),
                json_escape(&smp.name),
                smp.iters,
                smp.median_ns,
                smp.mean_ns,
                smp.stddev_ns,
                gbs,
                items,
                json_escape(&rev),
                if i + 1 < self.samples.len() { "," } else { "" },
            ));
        }
        s.push_str("]\n");
        let _ = std::fs::write(path, s);
    }

    /// Append collected samples to `results/bench.csv` (best-effort).
    pub fn write_csv(&self, bench_name: &str) {
        let _ = std::fs::create_dir_all("results");
        let path = "results/bench.csv";
        let mut body = String::new();
        for s in &self.samples {
            body.push_str(&format!("{bench_name},{}\n", s.csv_row()));
        }
        use std::io::Write;
        if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
            let _ = f.write_all(body.as_bytes());
        }
    }
}

/// Current short git revision (best-effort; "unknown" off-repo).
pub(crate) fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_positive_timing() {
        std::env::set_var("BENCH_QUICK", "1");
        let mut b = Bencher::new();
        let s = b.bench("noop_sum", || (0..100u64).sum::<u64>());
        assert!(s.median_ns > 0.0);
        assert!(s.iters > 0);
    }

    #[test]
    fn speedup_compares_medians() {
        let mut b = Bencher::new();
        b.samples.push(Sample {
            name: "fast".into(),
            iters: 1,
            median_ns: 100.0,
            mean_ns: 100.0,
            stddev_ns: 0.0,
            bytes_per_iter: None,
            items_per_iter: None,
        });
        b.samples.push(Sample {
            name: "slow".into(),
            iters: 1,
            median_ns: 700.0,
            mean_ns: 700.0,
            stddev_ns: 0.0,
            bytes_per_iter: None,
            items_per_iter: None,
        });
        assert!((b.speedup("fast", "slow").unwrap() - 7.0).abs() < 1e-12);
        assert!((b.speedup("slow", "fast").unwrap() - 1.0 / 7.0).abs() < 1e-12);
        assert!(b.speedup("fast", "missing").is_none());
        assert_eq!(b.report_speedup("fast", "slow"), b.speedup("fast", "slow"));
    }

    #[test]
    fn json_emission_is_parseable_shape() {
        std::env::set_var("BENCH_QUICK", "1");
        let mut b = Bencher::new();
        b.bench_bytes("json \"quoted\"/case", 1024, || 1 + 1);
        b.bench("plain", || 2 + 2);
        let mut path = std::env::temp_dir();
        path.push(format!("gratetile-benchkit-{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        b.write_json("unit", &path);
        let body = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(body.starts_with("[\n"));
        assert!(body.trim_end().ends_with(']'));
        assert_eq!(body.matches("\"git_rev\"").count(), 2);
        assert!(body.contains("json \\\"quoted\\\"/case"));
        assert!(body.contains("\"items_per_s\": null"));
        // Exactly one comma-separated boundary between the two objects.
        assert_eq!(body.matches("},\n").count(), 1);
    }

    #[test]
    fn json_escape_handles_specials() {
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("tab\tend"), "tab\\u0009end");
        assert_eq!(json_escape("plain"), "plain");
    }

    #[test]
    fn throughput_annotation() {
        std::env::set_var("BENCH_QUICK", "1");
        let mut b = Bencher::new();
        let buf = vec![1u8; 4096];
        let s = b.bench_bytes("sum4k", 4096, || buf.iter().map(|&x| x as u64).sum::<u64>());
        assert!(s.throughput_gbs().unwrap() > 0.0);
    }
}
