//! A miniature property-based testing harness (offline stand-in for
//! `proptest`).
//!
//! Supports: seeded case generation via [`SplitMix64`], a configurable
//! number of cases, and greedy input shrinking for generators that expose
//! a `shrink` step. Failures report the seed, the case index and the
//! (shrunk) input `Debug` rendering, so every failure is reproducible by
//! re-running with the printed seed.
//!
//! ```ignore
//! forall(0xC0FFEE, 256, gen_vec_f32, |v| prop_roundtrip(v));
//! ```

use super::rng::SplitMix64;
use std::fmt::Debug;

/// Number of cases run by default in `forall`.
pub const DEFAULT_CASES: usize = 256;

/// A generator: draws a value from the RNG.
pub trait Gen<T> {
    fn generate(&self, rng: &mut SplitMix64) -> T;

    /// Candidate "smaller" versions of a failing input. Default: none.
    fn shrink(&self, _value: &T) -> Vec<T> {
        Vec::new()
    }
}

/// Function generators: any `Fn(&mut SplitMix64) -> T` is a `Gen<T>`
/// without shrinking.
impl<T, F: Fn(&mut SplitMix64) -> T> Gen<T> for F {
    fn generate(&self, rng: &mut SplitMix64) -> T {
        self(rng)
    }
}

/// Run `prop` on `cases` generated inputs; panic with a reproducible
/// report on the first failure (after attempting to shrink it).
pub fn forall<T: Debug + Clone, G: Gen<T>>(
    seed: u64,
    cases: usize,
    gen: G,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = SplitMix64::new(seed);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if !prop(&input) {
            let shrunk = shrink_input(&gen, input, &prop);
            panic!(
                "property failed (seed={seed:#x}, case={case}/{cases})\n  input: {shrunk:?}"
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result<(), String>` so the
/// failure message can carry detail (e.g. which element mismatched).
pub fn forall_res<T: Debug + Clone, G: Gen<T>>(
    seed: u64,
    cases: usize,
    gen: G,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = SplitMix64::new(seed);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if let Err(msg) = prop(&input) {
            let ok = |t: &T| prop(t).is_ok();
            let shrunk = shrink_input(&gen, input, &ok);
            let final_msg = prop(&shrunk).err().unwrap_or_else(|| msg.clone());
            panic!(
                "property failed (seed={seed:#x}, case={case}/{cases}): {final_msg}\n  input: {shrunk:?}"
            );
        }
    }
}

/// Greedy shrink: repeatedly take the first shrink candidate that still
/// fails, up to a fixed depth to guarantee termination.
fn shrink_input<T: Debug + Clone, G: Gen<T>>(
    gen: &G,
    mut failing: T,
    prop: &impl Fn(&T) -> bool,
) -> T {
    for _ in 0..64 {
        let mut improved = false;
        for cand in gen.shrink(&failing) {
            if !prop(&cand) {
                failing = cand;
                improved = true;
                break;
            }
        }
        if !improved {
            break;
        }
    }
    failing
}

/// Generator for `Vec<f32>` with length in `[0, max_len]`, sparse with
/// probability `zero_p` (models ReLU feature-map words). Shrinks by
/// halving length and zeroing elements.
pub struct SparseVecGen {
    pub max_len: usize,
    pub zero_p: f64,
}

impl Gen<Vec<f32>> for SparseVecGen {
    fn generate(&self, rng: &mut SplitMix64) -> Vec<f32> {
        let len = rng.below(self.max_len + 1);
        (0..len)
            .map(|_| {
                if rng.chance(self.zero_p) {
                    0.0
                } else {
                    rng.next_f32() * 8.0 + 0.01
                }
            })
            .collect()
    }

    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if !v.is_empty() {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[v.len() / 2..].to_vec());
            if let Some(i) = v.iter().position(|&x| x != 0.0) {
                let mut z = v.clone();
                z[i] = 0.0;
                out.push(z);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(1, 64, |r: &mut SplitMix64| r.below(100), |&n| n < 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(2, 64, |r: &mut SplitMix64| r.below(100), |&n| n < 50);
    }

    #[test]
    fn shrinking_finds_smaller_counterexample() {
        // Property: all values are zero. The shrinker should drive the
        // failing vector down to something tiny.
        let gen = SparseVecGen { max_len: 64, zero_p: 0.5 };
        let mut rng = SplitMix64::new(3);
        let failing = loop {
            let v = gen.generate(&mut rng);
            if v.iter().any(|&x| x != 0.0) {
                break v;
            }
        };
        let shrunk = shrink_input(&gen, failing, &|v: &Vec<f32>| v.iter().all(|&x| x == 0.0));
        assert!(shrunk.iter().any(|&x| x != 0.0), "shrunk input must still fail");
        assert!(shrunk.len() <= 2, "expected aggressive shrink, got len {}", shrunk.len());
    }

    #[test]
    fn forall_res_reports_messages() {
        forall_res(4, 32, |r: &mut SplitMix64| r.below(8), |&n| {
            if n < 8 {
                Ok(())
            } else {
                Err(format!("{n} out of range"))
            }
        });
    }

    #[test]
    fn sparse_vec_gen_respects_bounds() {
        let gen = SparseVecGen { max_len: 32, zero_p: 0.9 };
        let mut rng = SplitMix64::new(5);
        for _ in 0..200 {
            let v = gen.generate(&mut rng);
            assert!(v.len() <= 32);
        }
    }
}
