//! Dependency-free error handling (offline stand-in for `anyhow`).
//!
//! The build image has no crates.io access, so the crate carries its own
//! minimal dynamic error: a message-carrying [`Error`], a [`Result`]
//! alias, the [`Context`] extension trait, and the [`err!`]/[`bail!`]
//! macros. Any `std::error::Error` converts into [`Error`] via `?`;
//! context calls prepend a `caller message: ` prefix exactly like
//! `anyhow::Context` renders single-cause chains.

use std::fmt;

/// A type-erased error: a rendered message chain.
///
/// Deliberately does *not* implement `std::error::Error`, so the blanket
/// `From<E: std::error::Error>` conversion below cannot collide with the
/// reflexive `From<Error> for Error` impl (the same trick `anyhow` uses).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error(msg.to_string())
    }

    /// Prepend a context layer to the message chain.
    pub fn context(self, msg: impl fmt::Display) -> Error {
        Error(format!("{msg}: {}", self.0))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{e}` and the `{e:#}` alternate form render the same chain.
        f.write_str(&self.0)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error(e.to_string())
    }
}

/// Crate-wide result alias (drop-in for `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a fallible value, like `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error with a fixed message.
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    /// Wrap the error with a lazily built message.
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error(format!("{msg}: {e}")))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error(msg.to_string()))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| Error(f()))
    }
}

/// Build an [`Error`] from a format string (stand-in for `anyhow!`).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] (stand-in for `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_failure() -> Result<i32> {
        let n: i32 = "not a number".parse()?; // ParseIntError -> Error via `?`
        Ok(n)
    }

    #[test]
    fn std_errors_convert_through_question_mark() {
        let e = parse_failure().unwrap_err();
        assert!(e.to_string().contains("invalid digit"), "{e}");
    }

    #[test]
    fn context_prepends_layers() {
        let e = parse_failure().context("reading config").unwrap_err();
        let rendered = format!("{e}");
        assert!(rendered.starts_with("reading config: "), "{rendered}");
        let e2 = Err::<(), _>(e).with_context(|| "outer".to_string()).unwrap_err();
        assert!(format!("{e2}").starts_with("outer: reading config: "));
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing key").unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }

    #[test]
    fn macros_format() {
        let e = err!("bad value {} at {}", 7, "line 3");
        assert_eq!(e.to_string(), "bad value 7 at line 3");
        fn bails() -> Result<()> {
            bail!("gave up after {} tries", 2)
        }
        assert_eq!(bails().unwrap_err().to_string(), "gave up after 2 tries");
    }

    #[test]
    fn alternate_display_matches_plain() {
        let e = err!("boom").context("ctx");
        assert_eq!(format!("{e:#}"), format!("{e}"));
    }
}
