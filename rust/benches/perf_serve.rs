//! Perf bench: the discrete-event serving simulator.
//!
//! §Perf acceptance (EXPERIMENTS.md, asserted below):
//!
//! * determinism: the simulated `ServerReport` bytes are identical for
//!   functional passes run with 1, 2 and 8 host workers — the report
//!   depends on the seed, never on `--jobs` or host load;
//! * worker scaling: simulated makespan strictly improves going from
//!   1 to 2 simulated accelerator workers (> 1× simulated throughput);
//! * bank-conflict sensitivity: on a DRAM-bound configuration, fewer
//!   banks never simulate faster (1 bank ≥ 8 banks in cycles);
//! * host speed: the timing pass (`simulate`) re-prices a request set
//!   without re-running the functional pass, so config sweeps are cheap.
//!
//! Results append to `results/bench.csv` and land machine-readable in
//! `BENCH_SERVE.json` at the repo root (CI uploads it per commit).

use gratetile::config::hardware::Platform;
use gratetile::config::layer::ConvLayer;
use gratetile::coordinator::simserver::{simulate, SimServer, SimServerConfig};
use gratetile::coordinator::{PipelineConfig, Weights};
use gratetile::util::benchkit::Bencher;
use gratetile::util::parallel::set_threads;

fn main() {
    let mut b = Bencher::new();
    let l1 = ConvLayer::new(1, 1, 32, 32, 8, 16);
    let l2 = ConvLayer::new(1, 2, 32, 32, 16, 16);
    let l3 = ConvLayer::new(1, 1, 16, 16, 16, 8);
    let layers = vec![
        (l1, Weights::random(&l1, 1)),
        (l2, Weights::random(&l2, 2)),
        (l3, Weights::random(&l3, 3)),
    ];
    let pipeline = PipelineConfig::new(Platform::NvidiaSmallTile.hardware());
    let mut cfg = SimServerConfig::new(pipeline);
    cfg.workers = 1;
    let server = SimServer::new(cfg, layers);
    let n = if b.is_quick() { 8 } else { 16 };
    let reqs = server.synthetic_requests(n, 0.4, 7);

    // ---- Determinism across host worker counts ----
    set_threads(1);
    let traces = server.functional_pass(&reqs).expect("functional pass @1");
    let r1 = simulate(&cfg, &traces);
    for jobs in [2usize, 8] {
        set_threads(jobs);
        let tj = server.functional_pass(&reqs).expect("functional pass");
        let rj = simulate(&cfg, &tj);
        assert_eq!(
            r1.render(),
            rj.render(),
            "simulated report must be byte-identical at --jobs {jobs}"
        );
    }
    set_threads(0);
    println!("serve/report determinism across jobs 1/2/8       byte-identical");

    // ---- Host speed: functional pass and timing pass ----
    b.bench_items("serve/functional_pass", n as u64, || {
        server.functional_pass(&reqs).expect("functional pass").len()
    });
    let mut c2 = cfg;
    c2.workers = 2;
    b.bench_items("serve/simulate@w2", n as u64, || {
        simulate(&c2, &traces).makespan_cycles
    });

    // ---- Simulated worker scaling ----
    let m1 = simulate(&cfg, &traces).makespan_cycles;
    let m2 = simulate(&c2, &traces).makespan_cycles;
    let mut c4 = cfg;
    c4.workers = 4;
    let m4 = simulate(&c4, &traces).makespan_cycles;
    let scale2 = m1 as f64 / m2 as f64;
    let scale4 = m1 as f64 / m4 as f64;
    println!("serve/sim worker scaling 1->2                    {scale2:>10.2}x  ({m1} -> {m2} cycles)");
    println!("serve/sim worker scaling 1->4                    {scale4:>10.2}x  ({m1} -> {m4} cycles)");
    assert!(
        scale2 > 1.0,
        "2 simulated workers must beat 1: {m1} -> {m2} cycles"
    );

    // ---- Bank-conflict sensitivity (DRAM-bound variant) ----
    // Traces carry raw MACs, so the DRAM-bound re-sweep needs no new
    // functional pass: just widen the PE array at simulate time.
    let mut cfg_dram = cfg;
    cfg_dram.pe_lanes = 1 << 30; // compute ≈ 1 cycle/layer
    cfg_dram.workers = 2;
    let mut by_banks = Vec::new();
    for banks in [1usize, 4, 8, 16] {
        let mut c = cfg_dram;
        c.timing.n_banks = banks;
        let r = simulate(&c, &traces);
        println!(
            "serve/sim banks={banks:<2} makespan {:>12} cycles  row-hit {:>5.1}%",
            r.makespan_cycles,
            r.row_hit_rate() * 100.0
        );
        by_banks.push((banks, r.makespan_cycles));
    }
    let cycles_of = |n: usize| by_banks.iter().find(|(b, _)| *b == n).unwrap().1;
    assert!(
        cycles_of(1) >= cycles_of(8),
        "more banks must not simulate slower: 1 bank {} vs 8 banks {}",
        cycles_of(1),
        cycles_of(8)
    );

    b.write_csv("perf_serve");
    b.write_json("perf_serve", "../BENCH_SERVE.json");
    println!("perf_serve: all acceptance asserts passed");
}
