//! Perf bench: the coordinator pipeline (fetch → decompress → conv),
//! double-buffered vs serialised prefetch. §Perf target: fetch and
//! compute overlap (overlap efficiency → 1.0) and tiles/s.

use gratetile::compress::Scheme;
use gratetile::config::hardware::Platform;
use gratetile::config::layer::ConvLayer;
use gratetile::coordinator::{LayerRunner, PipelineConfig, Weights};
use gratetile::tensor::sparsity::{generate, SparsityParams};
use gratetile::tiling::DivisionMode;
use gratetile::util::benchkit::Bencher;

fn main() {
    let layer = ConvLayer::new(1, 1, 56, 56, 32, 32);
    let fm = generate(56, 56, 32, SparsityParams::clustered(0.4, 11));
    let weights = Weights::random(&layer, 3);
    let mut b = Bencher::new();

    for depth in [1usize, 2, 4] {
        let mut cfg = PipelineConfig::new(Platform::NvidiaSmallTile.hardware());
        cfg.mode = DivisionMode::GrateTile { n: 8 };
        cfg.scheme = Scheme::Bitmask;
        cfg.prefetch_depth = depth;
        let runner = LayerRunner::new(cfg);
        let packed = runner.pack(&layer, &fm).unwrap();
        let mut last = None;
        b.bench(&format!("pipeline/56x56x32/depth{depth}"), || {
            let (_out, m) = runner.run_layer(&layer, &weights, &packed).unwrap();
            last = Some(m);
        });
        if let Some(m) = last {
            println!("  depth {depth}: {}", m.summary());
        }
    }
    b.write_csv("perf_pipeline");
}
