//! Perf bench: the coordinator pipeline (fetch → decompress → conv),
//! double-buffered vs serialised prefetch, plus the store-resident
//! variant (streamed compressed write-back, arena-addressed reads).
//! §Perf target: fetch and compute overlap (overlap efficiency → 1.0),
//! tiles/s, and the store chain's staging staying far below the dense
//! intermediate it replaces.

use gratetile::compress::Scheme;
use gratetile::config::hardware::Platform;
use gratetile::config::layer::ConvLayer;
use gratetile::coordinator::{LayerRunner, PipelineConfig, Weights};
use gratetile::store::TensorStore;
use gratetile::tensor::sparsity::{generate, SparsityParams};
use gratetile::tiling::DivisionMode;
use gratetile::util::benchkit::Bencher;

fn main() {
    let layer = ConvLayer::new(1, 1, 56, 56, 32, 32);
    let fm = generate(56, 56, 32, SparsityParams::clustered(0.4, 11));
    let weights = Weights::random(&layer, 3);
    let mut b = Bencher::new();

    for depth in [1usize, 2, 4] {
        let mut cfg = PipelineConfig::new(Platform::NvidiaSmallTile.hardware());
        cfg.mode = DivisionMode::GrateTile { n: 8 };
        cfg.policy = Scheme::Bitmask.into();
        cfg.prefetch_depth = depth;
        let runner = LayerRunner::new(cfg);
        let packed = runner.pack(&layer, &fm).unwrap();
        let mut last = None;
        b.bench(&format!("pipeline/56x56x32/depth{depth}"), || {
            let (_out, m) = runner.run_layer(&layer, &weights, &packed).unwrap();
            last = Some(m);
        });
        if let Some(m) = last {
            println!("  depth {depth}: {}", m.summary());
        }
    }

    // Store-resident chain: read from the store, stream compressed
    // write-back into it (no dense intermediate), timed-DRAM replay at
    // real addresses.
    {
        let mut cfg = PipelineConfig::new(Platform::NvidiaSmallTile.hardware());
        cfg.mode = DivisionMode::GrateTile { n: 8 };
        cfg.policy = Scheme::Bitmask.into();
        let runner = LayerRunner::new(cfg);
        let mut last = None;
        b.bench("pipeline/56x56x32/store-chain", || {
            let mut store = TensorStore::new();
            let layers = [(layer, weights.clone())];
            let per_layer = runner
                .run_network_in_store(&mut store, &layers, fm.clone(), "act")
                .unwrap();
            last = Some(per_layer.into_iter().next().unwrap());
        });
        if let Some(m) = last {
            println!("  store-chain: {}", m.summary());
            let dense_words = (layer.out_h() * layer.out_w() * layer.c_out) as u64;
            println!(
                "  store-chain: writeback {} KB (+{} B meta), staging peak {} of {} dense words",
                m.writeback_payload_bits / 8 / 1024,
                m.writeback_meta_bits / 8,
                m.peak_staged_words,
                dense_words,
            );
            assert!(
                m.peak_staged_words < dense_words,
                "streaming writer staged a whole dense map"
            );
        }
    }
    b.write_csv("perf_pipeline");
}
