//! Bench: regenerate Fig. 1 (power breakdown) and time the power model.

use gratetile::config::zoo::Network;
use gratetile::power::{network_power, ArrayConfig, EnergyTable};
use gratetile::util::benchkit::Bencher;

fn main() {
    let t = gratetile::harness::fig1();
    println!("{}", t.render());
    t.save_csv("fig1");

    let mut b = Bencher::new();
    let cfg = ArrayConfig::default();
    let e = EnergyTable::default();
    b.bench("fig1/power_model_all_networks", || {
        Network::all()
            .iter()
            .map(|&n| network_power(&cfg, &e, n).total_pj())
            .sum::<f64>()
    });
    b.write_csv("fig1_power");
}
