//! Perf bench: the bandwidth-simulator tile walk (the inner loop of
//! every table/figure regeneration). §Perf target: a full 23-layer
//! Table III sweep in < 2 s (measured end-to-end in table3_divisions).

use gratetile::compress::Scheme;
use gratetile::config::hardware::Platform;
use gratetile::config::layer::ConvLayer;
use gratetile::sim::experiment::run_layer;
use gratetile::tensor::sparsity::{generate, SparsityParams};
use gratetile::tiling::DivisionMode;
use gratetile::util::benchkit::Bencher;

fn main() {
    let mut b = Bencher::new();
    for (label, h, w, c) in [
        ("vgg_conv1_2/224x224x64", 224usize, 224usize, 64usize),
        ("vdsr/256x256x64", 256, 256, 64),
        ("alexnet_conv3/13x13x256", 13, 13, 256),
    ] {
        let layer = ConvLayer::new(1, 1, h, w, c, c);
        let fm = generate(h, w, c, SparsityParams::clustered(0.37, 7));
        let words = fm.words() as u64;
        for (m, mode) in [
            ("grate8", DivisionMode::GrateTile { n: 8 }),
            ("uniform4", DivisionMode::Uniform { edge: 4 }),
            ("uniform1", DivisionMode::Uniform { edge: 1 }),
        ] {
            let hw = Platform::NvidiaSmallTile.hardware();
            b.bench_items(&format!("walk/{label}/{m}"), words, || {
                run_layer(&hw, &layer, &fm, mode, Scheme::Bitmask).map(|r| r.fetched_bits)
            });
        }
    }
    b.write_csv("perf_walk");
}
