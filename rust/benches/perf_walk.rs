//! Perf bench: the bandwidth-simulator tile walk (the inner loop of
//! every table/figure regeneration), measuring both pricing paths in
//! the same run:
//!
//! * `walk/...` — the production `run_layer` end to end (pack + prefix
//!   pricer), the path every suite sweep takes.
//! * `price/.../prefix` vs `price/.../naive` — window pricing alone on
//!   the same pre-packed map: the prefix-sum pricer's 8-corner-lookup
//!   walk against the seed's per-sub-tensor triple loop.
//!
//! §Perf acceptance (EXPERIMENTS.md): on the vgg_conv1_2/224x224x64 ×
//! uniform1 case the prefix pricer must beat the naive walker by ≥ 5×
//! (asserted below). Property tests prove the two are bit-exact.

use gratetile::compress::Scheme;
use gratetile::config::hardware::Platform;
use gratetile::config::layer::ConvLayer;
use gratetile::layout::Packer;
use gratetile::sim::experiment::run_layer;
use gratetile::sim::pricer::{price_naive, LayerPricer};
use gratetile::sim::walker::TileWalker;
use gratetile::tensor::sparsity::{generate, SparsityParams};
use gratetile::tiling::{Division, DivisionMode};
use gratetile::util::benchkit::Bencher;

fn main() {
    let mut b = Bencher::new();
    let hw = Platform::NvidiaSmallTile.hardware();
    for (label, h, w, c) in [
        ("vgg_conv1_2/224x224x64", 224usize, 224usize, 64usize),
        ("vdsr/256x256x64", 256, 256, 64),
        ("alexnet_conv3/13x13x256", 13, 13, 256),
    ] {
        let layer = ConvLayer::new(1, 1, h, w, c, c);
        let fm = generate(h, w, c, SparsityParams::clustered(0.37, 7));
        let words = fm.words() as u64;
        for (m, mode) in [
            ("grate8", DivisionMode::GrateTile { n: 8 }),
            ("uniform4", DivisionMode::Uniform { edge: 4 }),
            ("uniform1", DivisionMode::Uniform { edge: 1 }),
        ] {
            // End-to-end production path (pack + prefix pricing).
            b.bench_items(&format!("walk/{label}/{m}"), words, || {
                run_layer(&hw, &layer, &fm, mode, Scheme::Bitmask).map(|r| r.fetched_bits)
            });

            // Pricing-only comparison on one shared packed map.
            let tile = hw.tile_for_layer(&layer);
            let division = Division::build(mode, &layer, &tile, &hw, h, w, c).unwrap();
            let packed = Packer::new(hw, Scheme::Bitmask).pack(&fm, &division, false);
            let walker = TileWalker::new(layer, tile);
            let pricer = LayerPricer::new(&packed);
            let fast_name = format!("price/{label}/{m}/prefix");
            let slow_name = format!("price/{label}/{m}/naive");
            b.bench_items(&fast_name, walker.n_tiles(), || pricer.price(&walker));
            b.bench_items(&slow_name, walker.n_tiles(), || price_naive(&packed, &walker));
            assert_eq!(
                pricer.price(&walker),
                price_naive(&packed, &walker),
                "pricer must stay bit-exact with the naive walker on {label}/{m}"
            );
            let speedup = b.report_speedup(&fast_name, &slow_name).unwrap();
            if label == "vgg_conv1_2/224x224x64" && m == "uniform1" {
                assert!(
                    speedup >= 5.0,
                    "§Perf acceptance: prefix pricer must be ≥ 5x faster than the \
                     naive walker on {label}/{m}, measured {speedup:.1}x"
                );
            }
        }
    }
    b.write_csv("perf_walk");
    b.write_json("perf_walk", "../BENCH_WALK.json");
}
