//! Perf bench: the packer hot path (compress + address assignment).
//! §Perf target: ≥ 1 GB/s single-core feature-map packing (sizes-only).

use gratetile::compress::Scheme;
use gratetile::config::hardware::Platform;
use gratetile::config::layer::{ConvLayer, TileShape};
use gratetile::layout::Packer;
use gratetile::tensor::sparsity::{generate, SparsityParams};
use gratetile::tiling::{Division, DivisionMode};
use gratetile::util::benchkit::Bencher;

fn main() {
    let hw = Platform::NvidiaSmallTile.hardware();
    let layer = ConvLayer::new(1, 1, 224, 224, 64, 64);
    let tile = TileShape::new(8, 16, 8);
    let fm = generate(224, 224, 64, SparsityParams::clustered(0.37, 42));
    let bytes = (fm.words() * 2) as u64;
    let mut b = Bencher::new();

    for (label, mode) in [
        ("grate8", DivisionMode::GrateTile { n: 8 }),
        ("uniform8", DivisionMode::Uniform { edge: 8 }),
        ("uniform1", DivisionMode::Uniform { edge: 1 }),
    ] {
        let division = Division::build(mode, &layer, &tile, &hw, 224, 224, 64).unwrap();
        for (suffix, scheme) in [("bitmask", Scheme::Bitmask), ("zrlc", Scheme::Zrlc)] {
            let packer = Packer::new(hw, scheme);
            b.bench_bytes(&format!("pack/{label}/{suffix}/sizes_only"), bytes, || {
                packer.pack(&fm, &division, false).total_words
            });
        }
        let packer = Packer::new(hw, Scheme::Bitmask);
        b.bench_bytes(&format!("pack/{label}/bitmask/with_payload"), bytes, || {
            packer.pack(&fm, &division, true).total_words
        });
    }
    b.write_csv("perf_pack");
}
