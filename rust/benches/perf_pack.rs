//! Perf bench: the pack→fetch data plane, measuring the plan/execute
//! engine against the seed packer it replaced (kept as
//! `Packer::pack_reference`, the bit-exact oracle).
//!
//! §Perf acceptance (EXPERIMENTS.md, asserted below):
//!
//! * scan-free sizing: engine ≥ 2× the oracle on a single thread
//!   (sizes-only ZRLC pack of vgg_conv1_2-sized 224×224×64);
//! * parallel execute: > 1× going from 1 to 2 workers (the CI smoke
//!   gate), and ≥ 3× over the oracle at 8 workers on machines that
//!   have them;
//! * bit-exactness: engine output (sizes, bits, addresses, records,
//!   payload) identical to the oracle in the same run, for
//!   grate8/uniform8/uniform1 × all four codecs;
//! * window-decode fast path: a partial window decodes fewer words
//!   than whole-sub-tensor decoding.
//!
//! Results append to `results/bench.csv` and land machine-readable in
//! `BENCH_PACK.json` at the repo root (CI uploads it as an artifact).

use gratetile::compress::{CodecPolicy, Registry, Scheme};
use gratetile::config::hardware::Platform;
use gratetile::config::layer::{ConvLayer, TileShape};
use gratetile::layout::{Fetcher, Packer};
use gratetile::memsim::Dram;
use gratetile::tensor::sparsity::{generate, SparsityParams};
use gratetile::tiling::{Division, DivisionMode};
use gratetile::util::benchkit::Bencher;
use gratetile::util::parallel::set_threads;

fn main() {
    let hw = Platform::NvidiaSmallTile.hardware();
    let layer = ConvLayer::new(1, 1, 224, 224, 64, 64);
    let tile = TileShape::new(8, 16, 8);
    let fm = generate(224, 224, 64, SparsityParams::clustered(0.37, 42));
    let bytes = (fm.words() * 2) as u64;
    let grate = Division::build(DivisionMode::GrateTile { n: 8 }, &layer, &tile, &hw, 224, 224, 64)
        .unwrap();
    let mut b = Bencher::new();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    // ---- Plan phase: scan-free sizing vs the oracle's triple scan ----
    // ZRLC sizes-only is the honest comparison: the seed gathers every
    // block and token-scans it twice; the engine streams one fused
    // stats pass per sub-tensor.
    let zrlc = Packer::new(hw, Scheme::Zrlc);
    set_threads(1);
    b.bench_bytes("pack/grate8/zrlc/sizes/oracle", bytes, || {
        zrlc.pack_reference(&fm, &grate, false).total_words
    });
    b.bench_bytes("pack/grate8/zrlc/sizes/engine@1", bytes, || {
        zrlc.pack(&fm, &grate, false).total_words
    });
    let plan_speedup = b
        .report_speedup("pack/grate8/zrlc/sizes/engine@1", "pack/grate8/zrlc/sizes/oracle")
        .unwrap();

    // ---- Execute phase: parallel payload materialisation ----
    let bitmask = Packer::new(hw, Scheme::Bitmask);
    b.bench_bytes("pack/grate8/bitmask/payload/oracle", bytes, || {
        bitmask.pack_reference(&fm, &grate, true).total_words
    });
    b.bench_bytes("pack/grate8/bitmask/payload/engine@1", bytes, || {
        bitmask.pack(&fm, &grate, true).total_words
    });
    set_threads(2);
    b.bench_bytes("pack/grate8/bitmask/payload/engine@2", bytes, || {
        bitmask.pack(&fm, &grate, true).total_words
    });
    let scale2 = b
        .speedup("pack/grate8/bitmask/payload/engine@2", "pack/grate8/bitmask/payload/engine@1")
        .unwrap();
    println!("pack/grate8/bitmask/payload 2-worker scaling      {scale2:>10.2}x");
    let mut speedup8 = None;
    if cores >= 8 {
        set_threads(8);
        b.bench_bytes("pack/grate8/bitmask/payload/engine@8", bytes, || {
            bitmask.pack(&fm, &grate, true).total_words
        });
        speedup8 = b.report_speedup(
            "pack/grate8/bitmask/payload/engine@8",
            "pack/grate8/bitmask/payload/oracle",
        );
    }
    set_threads(0);

    // ---- Adaptive planning overhead (ISSUE 5 CI gate) ----
    // Sizes-only packs time exactly the plan phase. The adaptive pass
    // runs ONE fused stats scan tracking the union of every codec's
    // needs (the same scan the dictionary codec already pays) plus four
    // closed-form evaluations, so it must stay within 10% of the most
    // demanding fixed codec's plan. BENCH_ADAPT.json records the
    // trajectory.
    let mut ba = Bencher::new();
    set_threads(1);
    let mut worst_fixed = f64::MIN;
    for scheme in Registry::global().schemes() {
        let packer = Packer::new(hw, scheme);
        let s = ba.bench_bytes(
            &format!("plan/grate8/{}/sizes@1", scheme.name()),
            bytes,
            || packer.pack(&fm, &grate, false).total_words,
        );
        worst_fixed = worst_fixed.max(s.median_ns);
    }
    let auto_packer = Packer::new(hw, CodecPolicy::Adaptive);
    let auto_ns = ba
        .bench_bytes("plan/grate8/auto/sizes@1", bytes, || {
            auto_packer.pack(&fm, &grate, false).total_words
        })
        .median_ns;
    set_threads(0);
    let overhead = auto_ns / worst_fixed;
    println!("plan/grate8 adaptive vs worst fixed codec          {overhead:>10.2}x");
    assert!(
        overhead < 1.10,
        "ISSUE 5 acceptance: adaptive planning must add <10% plan-phase \
         overhead vs fixed (worst fixed codec baseline), measured {overhead:.2}x"
    );
    ba.write_csv("perf_adapt");
    ba.write_json("perf_adapt", "../BENCH_ADAPT.json");

    // ---- The classic mode sweep (perf trajectory continuity) ----
    for (label, mode) in [
        ("grate8", DivisionMode::GrateTile { n: 8 }),
        ("uniform8", DivisionMode::Uniform { edge: 8 }),
        ("uniform1", DivisionMode::Uniform { edge: 1 }),
    ] {
        let division = Division::build(mode, &layer, &tile, &hw, 224, 224, 64).unwrap();
        let packer = Packer::new(hw, Scheme::Bitmask);
        b.bench_bytes(&format!("pack/{label}/bitmask/sizes_only"), bytes, || {
            packer.pack(&fm, &division, false).total_words
        });
    }

    // ---- Bit-exactness: engine == oracle in this very run ----
    for (label, mode) in [
        ("grate8", DivisionMode::GrateTile { n: 8 }),
        ("uniform8", DivisionMode::Uniform { edge: 8 }),
        ("uniform1", DivisionMode::Uniform { edge: 1 }),
    ] {
        let division = Division::build(mode, &layer, &tile, &hw, 224, 224, 64).unwrap();
        for scheme in [Scheme::Bitmask, Scheme::Zrlc, Scheme::Dictionary, Scheme::Raw] {
            let packer = Packer::new(hw, scheme);
            let oracle = packer.pack_reference(&fm, &division, true);
            let engine = packer.pack(&fm, &division, true);
            assert_eq!(oracle.sizes_words, engine.sizes_words, "{label}/{scheme:?} sizes");
            assert_eq!(oracle.sizes_bits, engine.sizes_bits, "{label}/{scheme:?} bits");
            assert_eq!(oracle.addr_words, engine.addr_words, "{label}/{scheme:?} addrs");
            assert_eq!(oracle.total_words, engine.total_words, "{label}/{scheme:?} total");
            assert_eq!(oracle.payload, engine.payload, "{label}/{scheme:?} payload");
            for (ra, rb) in oracle.metadata.records.iter().zip(&engine.metadata.records) {
                assert_eq!(ra.pointer_words, rb.pointer_words, "{label}/{scheme:?} pointer");
                assert_eq!(ra.sizes_words, rb.sizes_words, "{label}/{scheme:?} record");
            }
        }
    }
    println!("bit-exactness: engine == oracle on 3 modes x 4 codecs   OK");

    // ---- Window-decode fast path: partial < full ----
    {
        let division =
            Division::build(DivisionMode::Uniform { edge: 8 }, &layer, &tile, &hw, 224, 224, 64)
                .unwrap();
        let packed = Packer::new(hw, Scheme::Bitmask).pack(&fm, &division, true);
        let (y0, y1, x0, x1, c0, c1) = (0usize, 10usize, 0usize, 10usize, 0usize, 8usize);
        let touched: u64 = packed
            .division
            .intersecting(y0, y1, x0, x1, c0, c1)
            .iter()
            .map(|&r| packed.division.subtensor_words(r) as u64)
            .sum();
        let mut fetcher = Fetcher::new(&packed);
        let mut dram = Dram::default();
        let _ = fetcher.fetch_window(&mut dram, y0, y1, x0, x1, c0, c1);
        assert!(
            fetcher.decoded_words() < touched,
            "partial-window fast path decoded {} of {touched} touched words",
            fetcher.decoded_words()
        );
        println!(
            "fetch fast path: partial window decoded {} of {} touched words   OK",
            fetcher.decoded_words(),
            touched
        );
        b.bench_items("fetch/uniform8/bitmask/partial_window", touched, || {
            let mut d = Dram::default();
            fetcher.fetch_window(&mut d, y0, y1, x0, x1, c0, c1).data.len()
        });
    }

    // ---- Acceptance gates ----
    assert!(
        plan_speedup >= 2.0,
        "§Perf acceptance: scan-free sizing must be ≥ 2x the seed packer \
         single-threaded, measured {plan_speedup:.2}x"
    );
    assert!(
        scale2 > 1.0,
        "§Perf acceptance: parallel execute must scale > 1x on 2 workers, \
         measured {scale2:.2}x"
    );
    if let Some(s8) = speedup8 {
        assert!(
            s8 >= 3.0,
            "§Perf acceptance: engine at 8 workers must be ≥ 3x the seed \
             packer, measured {s8:.2}x"
        );
    } else {
        println!("(8-worker gate skipped: {cores} cores available)");
    }

    b.write_csv("perf_pack");
    b.write_json("perf_pack", "../BENCH_PACK.json");
}
