//! Bench: regenerate Table III (the headline evaluation) and time the
//! two-platform, seven-mode, 23-layer sweep end to end.

use gratetile::compress::Scheme;
use gratetile::util::benchkit::Bencher;
use gratetile::util::parallel::threads_for;
use std::time::Instant;

fn main() {
    // Pricing units fanned by the suite engine: platforms × modes × layers.
    let units = 2
        * gratetile::tiling::DivisionMode::table3_modes().len()
        * gratetile::config::zoo::benchmark_suite().len();
    println!("suite engine: {} worker threads for {units} units", threads_for(units));
    let t0 = Instant::now();
    let t = gratetile::harness::table3(Scheme::Bitmask);
    let elapsed = t0.elapsed();
    println!("{}", t.render());
    t.save_csv("table3");
    println!("full Table III sweep: {:.2}s", elapsed.as_secs_f64());

    // Also regenerate with ZRLC (robustness of the result to the codec).
    let tz = gratetile::harness::table3(Scheme::Zrlc);
    println!("{}", tz.render());
    tz.save_csv("table3_zrlc");

    let mut b = Bencher::new();
    b.bench("table3/bitmask_full", || gratetile::harness::table3(Scheme::Bitmask));
    b.write_csv("table3_divisions");
}
