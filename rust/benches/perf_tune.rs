//! Perf bench: auto-tuner search cost and memoization payoff (ISSUE 9).
//!
//! §Perf acceptance (EXPERIMENTS.md, asserted below):
//!
//! * fidelity: the cold search is never worse than the best fixed
//!   preset (and the default plan) on every study layer, and a warm
//!   re-tune of the full zoo is 100% memo hits with a byte-identical
//!   manifest;
//! * the memoized full-zoo re-tune is interactive-class: median
//!   < 1 s for the whole study (in practice it is micro-seconds — a
//!   hash per layer — so the gate has orders-of-magnitude headroom).
//!
//! Timing gates are noisy on shared hosts, so the gate re-measures up
//! to five times before failing (latest sample wins). Results append to
//! `results/bench.csv` and land machine-readable in `BENCH_TUNE.json`
//! at the repo root (CI uploads it per commit).

use gratetile::config::hardware::Platform;
use gratetile::config::layer::ConvLayer;
use gratetile::config::zoo::network_layers;
use gratetile::harness::TUNE_STUDY_NETWORKS;
use gratetile::sim::experiment::bench_feature_map;
use gratetile::tensor::FeatureMap;
use gratetile::tune::Tuner;
use gratetile::util::benchkit::Bencher;

fn main() {
    let mut b = Bencher::new();
    let hw = Platform::EyerissLargeTile.hardware();

    // The full default study zoo, maps synthesised once up front.
    let layers: Vec<(String, ConvLayer, FeatureMap)> = TUNE_STUDY_NETWORKS
        .iter()
        .flat_map(|&net| network_layers(net))
        .map(|bl| {
            let fm = bench_feature_map(&bl);
            (format!("{}.{}", bl.network.name(), bl.name), bl.layer, fm)
        })
        .collect();
    let n = layers.len() as u64;

    // ---- Fidelity: cold search quality, then warm bit-identity ----
    let mut tuner = Tuner::new(hw);
    let (manifest, results) = tuner.tune_network(&layers);
    let mut nodes = 0u64;
    let mut pruned = 0u64;
    for (r, (name, _, _)) in results.iter().zip(&layers) {
        assert!(!r.memo_hit, "{name}: cold pass must not memo-hit");
        assert!(
            r.total_bits() <= r.best_preset_total,
            "{name}: tuned {} > best preset {}",
            r.total_bits(),
            r.best_preset_total
        );
        assert!(
            r.best_preset_total <= r.default_total,
            "{name}: best preset worse than the default plan"
        );
        nodes += r.nodes;
        pruned += r.pruned;
    }
    println!(
        "tune cold quality      {n} layers, {nodes} nodes priced, {pruned} pruned, never worse"
    );
    let (warm_manifest, warm) = tuner.tune_network(&layers);
    assert!(warm.iter().all(|r| r.memo_hit), "warm full-zoo re-tune must be all memo hits");
    assert_eq!(
        warm_manifest.render(),
        manifest.render(),
        "memoized manifest bytes diverge from the cold search"
    );
    println!("tune warm fidelity     manifest byte-identical, {} memo hits", tuner.memo_hits);

    // ---- Measurements: cold search vs memoized re-tune ----
    b.bench_items("tune/cold/zoo", n, || Tuner::new(hw).tune_network(&layers).1.len());

    // ---- Gate: memoized full-zoo re-tune < 1 s median ----
    let mut med = f64::INFINITY;
    for attempt in 1..=5 {
        let s = b.bench_items("tune/warm/zoo", n, || tuner.tune_network(&layers).1.len());
        med = s.median_ns;
        println!("tune warm full-zoo     {:>10.1} us median  (attempt {attempt})", med / 1e3);
        if med < 1e9 {
            break;
        }
    }
    assert!(
        med < 1e9,
        "memoized full-zoo re-tune took {:.1} ms, breaching the 1 s gate",
        med / 1e6
    );
    b.report_speedup("tune/warm/zoo", "tune/cold/zoo");

    b.write_csv("perf_tune");
    b.write_json("perf_tune", "../BENCH_TUNE.json");
    println!("perf_tune: all acceptance asserts passed");
}
