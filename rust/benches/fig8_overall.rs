//! Bench: regenerate Fig. 8 (overall geomean bandwidth reduction) and
//! time the full-suite sweep.

use gratetile::compress::Scheme;
use gratetile::util::benchkit::Bencher;

fn main() {
    let mut b = Bencher::new();
    // The figure itself (also saved to results/fig8.csv).
    let t = gratetile::harness::fig8(Scheme::Bitmask);
    println!("{}", t.render());
    t.save_csv("fig8");
    // Timing: one platform suite sweep.
    let benches = gratetile::config::benchmark_suite();
    let hw = gratetile::config::Platform::NvidiaSmallTile.hardware();
    let modes = [gratetile::tiling::DivisionMode::GrateTile { n: 8 }];
    b.bench("fig8/suite_sweep_grate8_nvidia", || {
        gratetile::sim::experiment::run_suite(&hw, &benches, &modes, Scheme::Bitmask)
    });
    b.write_csv("fig8_overall");
}
