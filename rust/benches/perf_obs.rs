//! Perf bench: observability overhead gates (ISSUE 7).
//!
//! §Perf acceptance (EXPERIMENTS.md, asserted below):
//!
//! * disabled sink is near-free: threading a disabled `TraceRecorder`
//!   through the serving timing pass costs < 2% over the untraced
//!   `simulate`, and the pack path's per-codec bit attribution costs
//!   < 2% over a plain pack;
//! * enabled tracing stays cheap: full span + counter recording costs
//!   < 15% over the untraced timing pass;
//! * fidelity: the traced-but-disabled report renders byte-identical
//!   to the untraced one (the goldens' no-regression guarantee).
//!
//! Timing gates are noisy on shared hosts, so each gate re-measures
//! both sides (latest sample wins) up to five times before failing.
//! Results append to `results/bench.csv` and land machine-readable in
//! `BENCH_OBS.json` at the repo root (CI uploads it per commit).

use gratetile::config::hardware::Platform;
use gratetile::config::layer::{ConvLayer, TileShape};
use gratetile::coordinator::simserver::{simulate, simulate_traced, SimServer, SimServerConfig};
use gratetile::coordinator::{PipelineConfig, Weights};
use gratetile::layout::Packer;
use gratetile::obs::TraceRecorder;
use gratetile::tensor::sparsity::{generate, SparsityParams};
use gratetile::tiling::{Division, DivisionMode};
use gratetile::util::benchkit::Bencher;

/// Median-time overhead of `name` over `baseline`, in percent.
fn overhead_pct(b: &Bencher, name: &str, baseline: &str) -> f64 {
    let speedup = b.speedup(name, baseline).expect("both samples recorded");
    (1.0 / speedup - 1.0) * 100.0
}

fn main() {
    let mut b = Bencher::new();

    // ---- Serve workload: the perf_serve net, traces priced once ----
    let l1 = ConvLayer::new(1, 1, 32, 32, 8, 16);
    let l2 = ConvLayer::new(1, 2, 32, 32, 16, 16);
    let l3 = ConvLayer::new(1, 1, 16, 16, 16, 8);
    let layers = vec![
        (l1, Weights::random(&l1, 1)),
        (l2, Weights::random(&l2, 2)),
        (l3, Weights::random(&l3, 3)),
    ];
    let mut cfg =
        SimServerConfig::new(PipelineConfig::new(Platform::NvidiaSmallTile.hardware()));
    cfg.workers = 2;
    let server = SimServer::new(cfg, layers);
    let n = if b.is_quick() { 6 } else { 12 };
    let reqs = server.synthetic_requests(n, 0.4, 7);
    let traces = server.functional_pass(&reqs).expect("functional pass");

    // Fidelity first: a disabled recorder must not perturb the report.
    let plain = simulate(&cfg, &traces);
    let mut inert = TraceRecorder::disabled();
    let threaded = simulate_traced(&cfg, &traces, &mut inert);
    assert_eq!(
        plain.render(),
        threaded.render(),
        "disabled recorder must leave the report byte-identical"
    );
    println!("obs/serve disabled-sink report fidelity          byte-identical");

    // ---- Gate 1: disabled sink on the serving timing pass, < 2% ----
    let mut off_pct = f64::INFINITY;
    for attempt in 1..=5 {
        b.bench_items("obs/serve/untraced", n as u64, || {
            simulate(&cfg, &traces).makespan_cycles
        });
        b.bench_items("obs/serve/trace_disabled", n as u64, || {
            let mut rec = TraceRecorder::disabled();
            simulate_traced(&cfg, &traces, &mut rec).makespan_cycles
        });
        off_pct = overhead_pct(&b, "obs/serve/trace_disabled", "obs/serve/untraced");
        println!("obs/serve tracing-off overhead    {off_pct:>8.2}%  (attempt {attempt})");
        if off_pct < 2.0 {
            break;
        }
    }
    assert!(off_pct < 2.0, "disabled-sink serve overhead {off_pct:.2}% breaches the 2% gate");

    // ---- Gate 2: enabled tracing on the serving timing pass, < 15% ----
    let mut on_pct = f64::INFINITY;
    for attempt in 1..=5 {
        b.bench_items("obs/serve/untraced", n as u64, || {
            simulate(&cfg, &traces).makespan_cycles
        });
        b.bench_items("obs/serve/trace_enabled", n as u64, || {
            let mut rec = TraceRecorder::enabled();
            let makespan = simulate_traced(&cfg, &traces, &mut rec).makespan_cycles;
            (makespan, rec.spans().len())
        });
        on_pct = overhead_pct(&b, "obs/serve/trace_enabled", "obs/serve/untraced");
        println!("obs/serve tracing-on overhead     {on_pct:>8.2}%  (attempt {attempt})");
        if on_pct < 15.0 {
            break;
        }
    }
    assert!(on_pct < 15.0, "enabled-sink serve overhead {on_pct:.2}% breaches the 15% gate");

    // ---- Gate 3: per-codec bit attribution on the pack path, < 2% ----
    // The pipeline folds `payload_bits_by_tag` into its metrics after
    // every pack; that accounting must be invisible next to the pack.
    let hw = Platform::NvidiaSmallTile.hardware();
    let layer = ConvLayer::new(1, 1, 112, 112, 32, 32);
    let tile = TileShape::new(8, 16, 8);
    let fm = generate(112, 112, 32, SparsityParams::clustered(0.4, 42));
    let bytes = (fm.words() * 2) as u64;
    let grate = Division::build(DivisionMode::GrateTile { n: 8 }, &layer, &tile, &hw, 112, 112, 32)
        .unwrap();
    let packer = Packer::new(hw, gratetile::compress::Scheme::Bitmask);
    let mut pack_pct = f64::INFINITY;
    for attempt in 1..=5 {
        b.bench_bytes("obs/pack/plain", bytes, || packer.pack(&fm, &grate, true).total_words);
        b.bench_bytes("obs/pack/codec_attribution", bytes, || {
            let packed = packer.pack(&fm, &grate, true);
            let bits: u64 = packed.payload_bits_by_tag().iter().sum();
            (packed.total_words, bits)
        });
        pack_pct = overhead_pct(&b, "obs/pack/codec_attribution", "obs/pack/plain");
        println!("obs/pack bit-attribution overhead {pack_pct:>8.2}%  (attempt {attempt})");
        if pack_pct < 2.0 {
            break;
        }
    }
    assert!(pack_pct < 2.0, "pack attribution overhead {pack_pct:.2}% breaches the 2% gate");

    b.write_csv("perf_obs");
    b.write_json("perf_obs", "../BENCH_OBS.json");
    println!("perf_obs: all acceptance asserts passed");
}
