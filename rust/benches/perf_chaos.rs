//! Perf bench: integrity-verification overhead gate (ISSUE 8).
//!
//! §Perf acceptance (EXPERIMENTS.md, asserted below):
//!
//! * fault-free checksum verification is near-free: running the
//!   functional serving pass with the fetch-time verify layer enabled
//!   (`IntegrityPolicy::default()`, every sub-tensor read FNV-checked)
//!   costs < 3% over the unverified pass;
//! * fidelity: the verified fault-free serving report carries the same
//!   output checksum as the unverified one, with zero mismatches and
//!   zero degraded requests — verification observes, never perturbs.
//!
//! Timing gates are noisy on shared hosts, so the gate re-measures both
//! sides (latest sample wins) up to five times before failing. Results
//! append to `results/bench.csv` and land machine-readable in
//! `BENCH_CHAOS.json` at the repo root (CI uploads it per commit).

use gratetile::config::hardware::Platform;
use gratetile::config::layer::ConvLayer;
use gratetile::coordinator::simserver::{SimServer, SimServerConfig};
use gratetile::coordinator::{PipelineConfig, Weights};
use gratetile::layout::IntegrityPolicy;
use gratetile::util::benchkit::Bencher;

/// Median-time overhead of `name` over `baseline`, in percent.
fn overhead_pct(b: &Bencher, name: &str, baseline: &str) -> f64 {
    let speedup = b.speedup(name, baseline).expect("both samples recorded");
    (1.0 / speedup - 1.0) * 100.0
}

fn main() {
    let mut b = Bencher::new();

    // The perf_serve/perf_obs net: 3 layers, store-resident, measured
    // kernels — the verify layer sits in its fetch lane.
    let l1 = ConvLayer::new(1, 1, 32, 32, 8, 16);
    let l2 = ConvLayer::new(1, 2, 32, 32, 16, 16);
    let l3 = ConvLayer::new(1, 1, 16, 16, 16, 8);
    let layers = vec![
        (l1, Weights::random(&l1, 1)),
        (l2, Weights::random(&l2, 2)),
        (l3, Weights::random(&l3, 3)),
    ];
    let plain_cfg =
        SimServerConfig::new(PipelineConfig::new(Platform::NvidiaSmallTile.hardware()));
    let mut verify_cfg = plain_cfg;
    verify_cfg.pipeline.integrity = Some(IntegrityPolicy::default());

    let plain_server = SimServer::new(plain_cfg, layers.clone());
    let verify_server = SimServer::new(verify_cfg, layers);
    let n = if b.is_quick() { 6 } else { 12 };
    let reqs = plain_server.synthetic_requests(n, 0.4, 7);

    // Fidelity first: fault-free verification must not perturb a byte.
    let plain = plain_server.serve(reqs.clone()).expect("plain serve");
    let verified = verify_server.serve(reqs.clone()).expect("verified serve");
    assert_eq!(
        plain.output_checksum, verified.output_checksum,
        "fault-free verification changed the serving outputs"
    );
    assert_eq!(verified.checksum_mismatches, 0, "fault-free run flagged a mismatch");
    assert_eq!(verified.degraded_requests, 0, "fault-free run degraded a request");
    assert!(verified.verified_reads > 0, "the verify layer never actually ran");
    println!("chaos/verify fault-free output fidelity      byte-identical");

    // ---- Gate: fault-free verify overhead on the functional pass, < 3% ----
    let mut pct = f64::INFINITY;
    for attempt in 1..=5 {
        b.bench_items("chaos/functional/plain", n as u64, || {
            plain_server.functional_pass(&reqs).expect("functional pass").len()
        });
        b.bench_items("chaos/functional/verified", n as u64, || {
            verify_server.functional_pass(&reqs).expect("functional pass").len()
        });
        pct = overhead_pct(&b, "chaos/functional/verified", "chaos/functional/plain");
        println!("chaos fault-free verify overhead  {pct:>8.2}%  (attempt {attempt})");
        if pct < 3.0 {
            break;
        }
    }
    assert!(pct < 3.0, "fault-free checksum-verify overhead {pct:.2}% breaches the 3% gate");

    b.write_csv("perf_chaos");
    b.write_json("perf_chaos", "../BENCH_CHAOS.json");
    println!("perf_chaos: all acceptance asserts passed");
}
