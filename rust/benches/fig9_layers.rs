//! Bench: regenerate Fig. 9a/b (per-layer bandwidth reduction).

use gratetile::compress::Scheme;
use gratetile::config::Platform;

fn main() {
    for (name, p) in [
        ("fig9a", Platform::NvidiaSmallTile),
        ("fig9b", Platform::EyerissLargeTile),
    ] {
        let t = gratetile::harness::fig9(p, Scheme::Bitmask);
        println!("{}", t.render());
        t.save_csv(name);
    }
}
