//! §Perf: the GEMM compute backend — zero-skip elision vs the honest
//! dense baseline (`cargo bench --bench perf_gemm`).
//!
//! What this measures and gates (ISSUE 6 acceptance):
//! * The skip-policy ladder (`dense` → `valueskip` → `zeroskip`) on one
//!   representative 3x3 layer across the density range. At ≤ 25%
//!   density the fused zero-skip path must be **≥ 1.5x** faster than
//!   the no-skip kernel end to end (pack + fetch + kernel).
//! * On near-dense input (~0.9 density) zero-skip must not regress the
//!   dense baseline by more than 5% — the gates have to be free when
//!   there is nothing to skip.
//! * Every timed configuration is first checked **bit-identical** to
//!   the `direct_conv_relu` oracle, so the speedup is never bought with
//!   numerics drift.
//!
//! Throughput is reported in dense-equivalent MACs/s (`items/s`): the
//! skip policies do *less* work for the same result, so their
//! effective MAC rate rises with sparsity.
//!
//! Results append to `results/bench.csv` and land machine-readable in
//! `BENCH_GEMM.json` at the repo root (git-rev-stamped; CI uploads it
//! per commit).

use gratetile::compute::{GemmBackend, SkipPolicy};
use gratetile::config::hardware::Platform;
use gratetile::config::layer::ConvLayer;
use gratetile::coordinator::conv::{direct_conv_relu, Weights};
use gratetile::tensor::sparsity::{generate, SparsityParams};
use gratetile::util::benchkit::Bencher;
use gratetile::util::parallel::set_threads;

fn main() {
    let mut b = Bencher::new();
    // The kernel itself is single-threaded per tile; pin the host pool
    // so pack-phase parallelism does not blur the kernel comparison.
    set_threads(1);
    let hw = Platform::NvidiaSmallTile.hardware();

    // ---- Skip-policy ladder across the density range ----
    let layer = ConvLayer::new(1, 1, 48, 48, 32, 32);
    let wts = Weights::random(&layer, 5);
    for density in [0.10f64, 0.20, 0.50, 0.90] {
        let fm = generate(48, 48, 32, SparsityParams::clustered(density, 11));
        let oracle = direct_conv_relu(&layer, &wts, &fm);
        for skip in SkipPolicy::all() {
            let be = GemmBackend::new(hw).with_skip(skip);
            let run = be.conv_relu(&layer, &wts, &fm).unwrap();
            assert_eq!(
                run.out.as_slice(),
                oracle.as_slice(),
                "bit-exactness vs the direct-conv oracle failed at \
                 d={density:.2} under {}",
                skip.name()
            );
            let dense_macs = run.stats.dense_macs;
            b.bench_items(
                &format!("gemm/48x48x32->32/d{density:.2}/{}", skip.name()),
                dense_macs,
                || be.conv_relu(&layer, &wts, &fm).unwrap(),
            );
        }
        let zs = format!("gemm/48x48x32->32/d{density:.2}/zeroskip");
        let dn = format!("gemm/48x48x32->32/d{density:.2}/dense");
        let speedup = b.report_speedup(&zs, &dn).unwrap();
        if density <= 0.25 {
            assert!(
                speedup >= 1.5,
                "§Perf acceptance: zero-skip must be ≥ 1.5x the no-skip \
                 kernel at d={density:.2}, measured {speedup:.2}x"
            );
        }
        if density >= 0.89 {
            assert!(
                speedup >= 1.0 / 1.05,
                "§Perf acceptance: zero-skip must not regress the dense \
                 baseline by > 5% on near-dense input (d={density:.2}), \
                 measured {speedup:.2}x"
            );
        }
    }

    // ---- Strided layer spot check (no gate; trajectory data) ----
    let strided = ConvLayer::new(1, 2, 48, 48, 32, 32);
    let swts = Weights::random(&strided, 7);
    let sfm = generate(48, 48, 32, SparsityParams::clustered(0.2, 13));
    let soracle = direct_conv_relu(&strided, &swts, &sfm);
    for skip in [SkipPolicy::Dense, SkipPolicy::ZeroSkip] {
        let be = GemmBackend::new(hw).with_skip(skip);
        let run = be.conv_relu(&strided, &swts, &sfm).unwrap();
        assert_eq!(run.out.as_slice(), soracle.as_slice(), "strided/{}", skip.name());
        let dense_macs = run.stats.dense_macs;
        b.bench_items(
            &format!("gemm/48x48x32->32/s2/d0.20/{}", skip.name()),
            dense_macs,
            || be.conv_relu(&strided, &swts, &sfm).unwrap(),
        );
    }
    b.report_speedup(
        "gemm/48x48x32->32/s2/d0.20/zeroskip",
        "gemm/48x48x32->32/s2/d0.20/dense",
    );

    set_threads(0);
    b.write_csv("perf_gemm");
    b.write_json("perf_gemm", "../BENCH_GEMM.json");
    println!("perf_gemm: all acceptance asserts passed");
}
