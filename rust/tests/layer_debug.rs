//! Debug dump: per-layer savings for a few division modes.
use gratetile::compress::Scheme;
use gratetile::config::{benchmark_suite, Platform};
use gratetile::sim::experiment::{bench_feature_map, run_bench_layer};
use gratetile::tiling::DivisionMode;

#[test]
#[ignore = "debug dump"]
fn per_layer_dump() {
    let hw = Platform::NvidiaSmallTile.hardware();
    for b in benchmark_suite() {
        let fm = bench_feature_map(&b);
        let mut line = format!("{:<18} d={:.2}", format!("{} {}", b.network.name(), b.name), fm.density());
        for mode in [
            DivisionMode::GrateTile { n: 8 },
            DivisionMode::Uniform { edge: 8 },
            DivisionMode::Uniform { edge: 4 },
            DivisionMode::Uniform { edge: 1 },
        ] {
            match run_bench_layer(&hw, &b, mode, Scheme::Bitmask, &fm) {
                Ok(r) => line.push_str(&format!(
                    "  {}={:>6.1}%",
                    mode.name().replace("Uniform ", "u").replace("GrateTile (mod ", "g").replace(')', ""),
                    r.saving_with_meta() * 100.0
                )),
                Err(_) => line.push_str("  N/A"),
            }
        }
        println!("{line}");
    }
}
