//! `.grate` container format-compatibility suite (ISSUE 5).
//!
//! The v2 format added the codec-policy byte and the adaptive tag
//! table; the reader must keep accepting v1 containers forever. The v1
//! fixture in `tests/golden/fixture_v1.grate` is blessed on first run
//! (the authoring container cannot execute the crate) and byte-pinned
//! afterwards: later sessions open the *checked-in* bytes, so any
//! accidental v1-reader regression — or any drift in what v1 bytes we
//! produce — fails loudly.

use gratetile::compress::{CodecPolicy, Scheme};
use gratetile::config::hardware::Platform;
use gratetile::config::layer::ConvLayer;
use gratetile::layout::Packer;
use gratetile::memsim::Dram;
use gratetile::store::{Container, TensorStore};
use gratetile::tensor::sparsity::{generate, SparsityParams};
use gratetile::tensor::FeatureMap;
use gratetile::tiling::division::{Division, DivisionMode};
use std::path::{Path, PathBuf};

fn golden_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// The deterministic map every compat test serves: same seed, same
/// geometry, forever (changing it would orphan the fixture).
fn fixture_map() -> (FeatureMap, Division) {
    let hw = Platform::NvidiaSmallTile.hardware();
    let layer = ConvLayer::new(1, 1, 24, 24, 16, 16);
    let tile = hw.tile_for_layer(&layer);
    let division =
        Division::build(DivisionMode::GrateTile { n: 8 }, &layer, &tile, &hw, 24, 24, 16)
            .unwrap();
    let fm = generate(24, 24, 16, SparsityParams::clustered(0.4, 77));
    (fm, division)
}

/// v1 backward compat against the checked-in fixture: bless the v1
/// bytes if absent, then open and serve windows bit-exactly against
/// the deterministic source map.
#[test]
fn v1_fixture_opens_and_serves_bit_exactly() {
    let hw = Platform::NvidiaSmallTile.hardware();
    let (fm, division) = fixture_map();
    let path = golden_dir().join("fixture_v1.grate");
    if !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        let packed = Packer::new(hw, Scheme::Bitmask).pack(&fm, &division, true);
        Container::write_with_version(&path, &[("act".to_string(), &packed)], 1).unwrap();
        eprintln!("container_compat: blessed {}", path.display());
    }
    // Structural pin on the raw bytes, independent of the reader: a v1
    // TOC entry is name_len ∥ name ∥ scheme byte ∥ division (tag,
    // param) with NO policy byte. If the v1 writer ever regressed into
    // emitting the v2 layout, the freshly blessed fixture would fail
    // these offsets — so the check bites even on the self-blessed first
    // run, where reader and writer could otherwise hide each other.
    let bytes = std::fs::read(&path).unwrap();
    assert_eq!(&bytes[..4], b"GRTC");
    assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), 1, "header version");
    const HEADER: usize = 28;
    assert_eq!(u16::from_le_bytes(bytes[HEADER..HEADER + 2].try_into().unwrap()), 3);
    assert_eq!(&bytes[HEADER + 2..HEADER + 5], b"act");
    assert_eq!(bytes[HEADER + 5], 0, "scheme byte (bitmask tag) directly after the name");
    assert_eq!(bytes[HEADER + 6], 1, "GrateTile division tag right after the scheme byte");
    assert_eq!(
        u32::from_le_bytes(bytes[HEADER + 7..HEADER + 11].try_into().unwrap()),
        8,
        "division modulus parameter"
    );

    let c = Container::open(&path).unwrap();
    assert_eq!(c.version, 1, "fixture must stay a genuine v1 file");
    c.verify().unwrap();
    let e = c.entry("act").unwrap();
    assert_eq!(e.packed.policy, CodecPolicy::Fixed(Scheme::Bitmask));
    assert!(e.packed.tags.is_empty(), "v1 tensors carry no codec tags");
    let mut dram = Dram::default();
    for (y0, y1, x0, x1) in [(0, 24, 0, 24), (5, 14, 3, 17), (23, 24, 0, 1)] {
        let win = c.fetch_window("act", &mut dram, y0, y1, x0, x1, 0, 16).unwrap();
        for y in y0..y1 {
            for x in x0..x1 {
                for ch in 0..16 {
                    assert_eq!(win.get(y, x, ch), fm.get(y, x, ch), "({y},{x},{ch})");
                }
            }
        }
    }
}

/// The satellite round trip: pack v2-adaptive → inspect (TOC/policy/
/// tags) → serve (window fetches off the file), all bit-exact.
#[test]
fn v2_adaptive_pack_inspect_serve_roundtrip() {
    let hw = Platform::NvidiaSmallTile.hardware();
    let (fm, division) = fixture_map();
    let packed = Packer::new(hw, CodecPolicy::Adaptive).pack(&fm, &division, true);
    let mut path = std::env::temp_dir();
    path.push(format!("gratetile-compat-v2-{}.grate", std::process::id()));
    // Pinned to version 2: the default writer moved on to v3 (per-sub-
    // tensor integrity checksums), and this test is the v2 compat pin.
    Container::write_with_version(&path, &[("act".to_string(), &packed)], 2).unwrap();

    // Inspect: v2 header, adaptive policy, intact tag table + records.
    let c = Container::open(&path).unwrap();
    assert_eq!(c.version, 2);
    c.verify().unwrap();
    let e = c.entry("act").unwrap();
    assert_eq!(e.packed.policy, CodecPolicy::Adaptive);
    assert_eq!(e.packed.tags, packed.tags);
    assert!(e.packed.codec_summary().starts_with("auto("));

    // Serve: windows off the file, and a store round trip through the
    // in-memory read path.
    let mut dram = Dram::default();
    let win = c.fetch_window("act", &mut dram, 2, 22, 1, 23, 0, 16).unwrap();
    for y in 2..22 {
        for x in 1..23 {
            for ch in 0..16 {
                assert_eq!(win.get(y, x, ch), fm.get(y, x, ch), "({y},{x},{ch})");
            }
        }
    }
    let mut store = TensorStore::new();
    store.insert_packed("act", &c.read_tensor("act").unwrap()).unwrap();
    let mut d2 = Dram::default();
    assert_eq!(store.fetch_dense("act", &mut d2).unwrap().as_slice(), fm.as_slice());
    std::fs::remove_file(&path).ok();
}

/// v3 (the default writer): the per-sub-tensor integrity checksum
/// table survives the TOC round trip bit-exactly, one checksum per
/// sub-tensor — the foundation the fetch-time verify/retry/quarantine
/// path stands on.
#[test]
fn v3_default_write_carries_checksums() {
    let hw = Platform::NvidiaSmallTile.hardware();
    let (fm, division) = fixture_map();
    let packed = Packer::new(hw, Scheme::Bitmask).pack(&fm, &division, true);
    let mut path = std::env::temp_dir();
    path.push(format!("gratetile-compat-v3-{}.grate", std::process::id()));
    Container::write(&path, &[("act".to_string(), &packed)]).unwrap();
    let c = Container::open(&path).unwrap();
    assert_eq!(c.version, 3);
    let e = c.entry("act").unwrap();
    assert_eq!(e.packed.checksums.len(), e.packed.sizes_words.len());
    assert_eq!(e.packed.checksums, packed.checksums);
    std::fs::remove_file(&path).ok();
}
