//! Runtime integration: load the AOT artifacts via PJRT and execute
//! them. Requires `make artifacts` (the Makefile test target runs it).

use gratetile::runtime::{Engine, Manifest};
use std::path::Path;

fn artifacts_dir() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").leak()
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.txt").exists()
}

#[test]
fn cnn_artifact_runs_and_yields_sparse_activations() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(artifacts_dir()).expect("manifest");
    let entry = manifest.get("cnn").expect("cnn entry");
    let engine = Engine::cpu().expect("cpu client");
    let model = engine.load_entry(entry).expect("compile cnn");

    // Structured synthetic image (gradient + blob), values in [0,1].
    let (h, w, c) = (entry.input_dims[0], entry.input_dims[1], entry.input_dims[2]);
    let image: Vec<f32> = (0..h * w * c)
        .map(|i| {
            let y = (i / (w * c)) as f32 / h as f32;
            let x = ((i / c) % w) as f32 / w as f32;
            (x * y + (10.0 * x).sin() * 0.1).max(0.0)
        })
        .collect();

    let fms = model.run_cnn(entry, &image).expect("run cnn");
    assert_eq!(fms.len(), entry.n_outputs);
    for (i, fm) in fms.iter().enumerate() {
        let (eh, ew, ec) = entry.layer_shapes[i];
        assert_eq!((fm.h, fm.w, fm.c), (eh, ew, ec), "layer {i} shape");
        // ReLU activations: nonnegative, nontrivially sparse.
        assert!(fm.as_slice().iter().all(|&v| v >= 0.0), "layer {i} negative");
        let d = fm.density();
        assert!(d > 0.05 && d < 0.98, "layer {i} density {d}");
    }
}

#[test]
fn compress_stats_artifact_matches_rust_bitmask() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(artifacts_dir()).expect("manifest");
    let entry = manifest.get("compress_stats").expect("entry");
    let engine = Engine::cpu().expect("cpu client");
    let model = engine.load_entry(entry).expect("compile stats");

    // 8 blocks of 512 with varied sparsity.
    let b = entry.input_dims[0];
    let n = entry.input_dims[1];
    let mut rng = gratetile::util::SplitMix64::new(77);
    let blocks: Vec<f32> = (0..b * n)
        .map(|i| {
            let density = 0.1 + 0.1 * ((i / n) as f64);
            if rng.chance(density) {
                rng.next_f32() + 0.01
            } else {
                0.0
            }
        })
        .collect();
    let outs = model.run_literals(&[(&blocks, &entry.input_dims)]).expect("run");
    assert_eq!(outs.len(), 2);
    let mask_dev: Vec<i32> = outs[0].to_vec::<i32>().expect("mask i32");
    let nnz_dev: Vec<i32> = outs[1].to_vec::<i32>().expect("nnz i32");
    assert_eq!(mask_dev.len(), b * 32);
    assert_eq!(nnz_dev.len(), b);

    // Bit-exact agreement with the Rust codec: the L1 kernel and the L3
    // packer must describe the same storage layout.
    use gratetile::compress::{Bitmask, Compressor};
    for bi in 0..b {
        let block = &blocks[bi * n..(bi + 1) * n];
        let comp = Bitmask.compress(block);
        let nnz = block.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nnz_dev[bi] as usize, nnz, "block {bi} nnz");
        for (j, &mw) in comp.words[..32].iter().enumerate() {
            assert_eq!(
                mask_dev[bi * 32 + j] as u16,
                mw,
                "block {bi} mask word {j}"
            );
        }
    }
}
