//! Tier-1 suite for the self-hosted invariant linter (ISSUE 10).
//!
//! Three layers of coverage:
//!
//! 1. **Fixtures** — for every rule, an embedded positive snippet that
//!    must flag (exact file:line asserted) and a negative snippet that
//!    must pass, exercising the path/test-region scoping.
//! 2. **Suppression round-trips** — pragma and allowlist acceptance,
//!    mandatory justifications, and the `unused-allow`/`bad-pragma`
//!    hygiene warnings.
//! 3. **Self-lint** — the full `src/` + `tests/` tree (these lines
//!    included) must come back with zero errors *and* zero warnings:
//!    every suppression in the tree is justified and load-bearing.

use gratetile::analysis::report::Severity;
use gratetile::analysis::{find_crate_root, lint_text, lint_tree};
use std::path::{Path, PathBuf};

fn crate_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).to_path_buf()
}

/// Error-severity findings as `(line, rule)` pairs.
fn errors_of(path: &str, text: &str) -> Vec<(usize, String)> {
    lint_text(path, text, "")
        .unwrap()
        .findings
        .iter()
        .filter(|f| f.severity == Severity::Error)
        .map(|f| {
            assert_eq!(f.path, path);
            assert!(!f.hint.is_empty(), "every finding carries a fix hint");
            (f.line, f.rule.to_string())
        })
        .collect()
}

fn assert_clean(path: &str, text: &str) {
    let got = errors_of(path, text);
    assert!(got.is_empty(), "expected no findings in {path}, got {got:?}");
}

// ---------------------------------------------------------------- rules

#[test]
fn nondet_iter_positive_and_negative() {
    let positive = "fn ok() {}\nuse std::collections::HashMap;\n";
    assert_eq!(errors_of("src/sim/x.rs", positive), [(2, "nondet-iter".to_string())]);
    // Fires in test code too — a hash-ordered test is a flaky test.
    assert_eq!(errors_of("tests/x.rs", positive), [(2, "nondet-iter".to_string())]);
    assert_clean("src/sim/x.rs", "use std::collections::BTreeMap;\n");
    // Tokens hidden in strings/comments are not code.
    assert_clean("src/sim/x.rs", "// HashMap\nlet s = \"HashMap\";\n");
    assert_clean("src/sim/x.rs", "struct MyHashMapLike;\n");
}

#[test]
fn wall_clock_positive_and_negative() {
    let positive = "fn f() {}\nfn g() {}\nlet t = Instant::now();\n";
    assert_eq!(errors_of("src/memsim/x.rs", positive), [(3, "wall-clock".to_string())]);
    assert_eq!(
        errors_of("src/x.rs", "use std::time::Duration;\n"),
        [(1, "wall-clock".to_string())]
    );
    assert_clean("src/memsim/x.rs", "let cycles: u64 = dram.busy_cycles();\n");
}

#[test]
fn panic_in_decoder_positive_and_negative() {
    let positive = "fn d(v: &[u16]) {\n    let x = v.first().unwrap();\n}\n";
    assert_eq!(
        errors_of("src/compress/x.rs", positive),
        [(2, "panic-in-decoder".to_string())]
    );
    assert_eq!(
        errors_of("src/store/container.rs", positive),
        [(2, "panic-in-decoder".to_string())]
    );
    // Same text outside the decoder surfaces is allowed...
    assert_clean("src/sim/x.rs", positive);
    // ...as is decoder test code (the in-test region starts at
    // `#[cfg(test)]` and runs to EOF),
    assert_clean("src/compress/x.rs", "fn ok() {}\n#[cfg(test)]\nmod t { fn f() { x.unwrap(); } }\n");
    // ...and the hardened patterns themselves.
    assert_clean("src/compress/x.rs", "let v = m.get(i).copied().unwrap_or(0);\n");
}

#[test]
fn stray_print_positive_and_negative() {
    let positive = "fn f() {\n    println!(\"x\");\n}\n";
    assert_eq!(errors_of("src/harness/x.rs", positive), [(2, "stray-print".to_string())]);
    // Entry points, the log sink, and test code may print.
    assert_clean("src/main.rs", positive);
    assert_clean("src/bin/gratetile-lint.rs", positive);
    assert_clean("src/obs/log.rs", positive);
    assert_clean("tests/x.rs", positive);
    assert_clean("src/harness/x.rs", "log_info!(\"x\");\n");
}

#[test]
fn env_read_positive_and_negative() {
    let positive = "fn f() {}\nlet v = std::env::var(\"GRATETILE_X\");\n";
    assert_eq!(errors_of("src/sim/x.rs", positive), [(2, "env-read".to_string())]);
    // Owner modules and the args() entry-point read are fine.
    assert_clean("src/config/x.rs", positive);
    assert_clean("src/util/x.rs", positive);
    assert_clean("src/main.rs", "let a: Vec<String> = std::env::args().collect();\n");
}

// --------------------------------------------------------- suppressions

#[test]
fn pragma_round_trip() {
    // Trailing pragma on the flagged line.
    let rep = lint_text(
        "src/sim/x.rs",
        "use std::collections::HashMap; // lint: allow(nondet-iter, lookup-only cache)\n",
        "",
    )
    .unwrap();
    assert_eq!((rep.errors(), rep.warnings(), rep.suppressed), (0, 0, 1), "{}", rep.render());

    // Standalone pragma line covers the next line.
    let rep = lint_text(
        "src/sim/x.rs",
        "// lint: allow(nondet-iter, lookup-only cache)\nuse std::collections::HashMap;\n",
        "",
    )
    .unwrap();
    assert_eq!((rep.errors(), rep.warnings(), rep.suppressed), (0, 0, 1));

    // A pragma for the wrong rule suppresses nothing: the finding stays
    // an error and the pragma is flagged as stale.
    let rep = lint_text(
        "src/sim/x.rs",
        "use std::collections::HashMap; // lint: allow(wall-clock, wrong)\n",
        "",
    )
    .unwrap();
    assert_eq!((rep.errors(), rep.warnings()), (1, 1));
}

#[test]
fn pragmas_require_reason_and_known_rule() {
    let rep = lint_text("src/x.rs", "fn f() {} // lint: allow(nondet-iter)\n", "").unwrap();
    assert_eq!(rep.findings[0].rule, "bad-pragma");
    let rep = lint_text("src/x.rs", "fn f() {} // lint: allow(bogus-rule, why)\n", "").unwrap();
    assert_eq!(rep.findings[0].rule, "bad-pragma");
    // Warnings pass by default but fail the CI mode.
    assert!(rep.ok(false) && !rep.ok(true));
}

#[test]
fn allowlist_round_trip() {
    let src = "let t = Instant::now();\n";
    let rep = lint_text("src/coordinator/x.rs", src, "").unwrap();
    assert_eq!(rep.errors(), 1);
    let rep = lint_text(
        "src/coordinator/x.rs",
        src,
        "# comment\nwall-clock src/coordinator/x.rs measures host wall time by design\n",
    )
    .unwrap();
    assert_eq!((rep.errors(), rep.warnings(), rep.suppressed), (0, 0, 1), "{}", rep.render());
    // Entries only cover their own (rule, path).
    let rep = lint_text(
        "src/coordinator/y.rs",
        src,
        "wall-clock src/coordinator/x.rs measures host wall time by design\n",
    )
    .unwrap();
    assert_eq!(rep.errors(), 1);
    // And the unmatched entry is reported as stale, at its line.
    let stale = rep.findings.iter().find(|f| f.path == "lint.allow").unwrap();
    assert_eq!((stale.rule, stale.line), ("unused-allow", 1));
}

#[test]
fn allowlist_justification_is_mandatory() {
    let e = lint_text("src/x.rs", "fn f() {}\n", "wall-clock src/x.rs\n").unwrap_err();
    assert!(e.to_string().contains("justification"), "{e}");
    assert!(e.to_string().contains("lint.allow:1"), "{e}");
}

// ------------------------------------------------------------ self-lint

#[test]
fn full_tree_self_lint_is_clean_including_suppression_hygiene() {
    let rep = lint_tree(&crate_root()).unwrap();
    assert_eq!(rep.errors(), 0, "unallowlisted findings:\n{}", rep.render());
    // Zero warnings too: every pragma and allowlist entry in the tree
    // is well-formed AND suppresses a live finding (no stale allows).
    assert_eq!(rep.warnings(), 0, "stale/malformed suppressions:\n{}", rep.render());
    assert!(rep.ok(true));
    assert!(rep.files_scanned > 80, "expected the whole tree, got {}", rep.files_scanned);
    assert!(rep.suppressed > 0, "the tree carries justified suppressions");
}

#[test]
fn report_is_deterministic_and_summarised() {
    let a = lint_tree(&crate_root()).unwrap();
    let b = lint_tree(&crate_root()).unwrap();
    assert_eq!(a.render(), b.render());
    let tail = a.render();
    let last = tail.lines().last().unwrap().to_string();
    assert!(last.starts_with("lint: ") && last.ends_with("suppressed"), "{last}");
}

#[test]
fn crate_root_resolves_from_repo_root_and_crate_dir() {
    let root = crate_root();
    assert_eq!(find_crate_root(&root).as_deref(), Some(root.as_path()));
    if let Some(repo) = root.parent() {
        // From the repository root the `rust/` crate is found instead.
        assert_eq!(find_crate_root(repo).as_deref(), Some(root.as_path()));
    }
}
