//! Cross-module integration tests: the analytic bandwidth simulator,
//! the packer/fetcher runtime path, and the coordinator pipeline must
//! tell one consistent story.

use gratetile::compress::Scheme;
use gratetile::config::hardware::Platform;
use gratetile::config::layer::ConvLayer;
use gratetile::coordinator::{direct_conv_relu, LayerRunner, PipelineConfig, Weights};
use gratetile::layout::{Fetcher, Packer};
use gratetile::memsim::{Dram, Stream};
use gratetile::sim::experiment::run_layer;
use gratetile::sim::walker::TileWalker;
use gratetile::tensor::sparsity::{generate, SparsityParams};
use gratetile::tiling::{Division, DivisionMode};

/// The fetcher (runtime path) and run_layer (analytic path) must account
/// identical metadata traffic and consistent feature traffic when
/// walking the same tile schedule.
#[test]
fn fetcher_and_simulator_agree_on_traffic() {
    let hw = Platform::NvidiaSmallTile.hardware();
    let layer = ConvLayer::new(1, 1, 40, 40, 16, 16);
    let fm = generate(40, 40, 16, SparsityParams::clustered(0.4, 5));
    let mode = DivisionMode::GrateTile { n: 8 };

    // Analytic.
    let analytic = run_layer(&hw, &layer, &fm, mode, Scheme::Bitmask).unwrap();

    // Runtime: drive the fetcher over the same schedule.
    let tile = hw.tile_for_layer(&layer);
    let division = Division::build(mode, &layer, &tile, &hw, 40, 40, 16).unwrap();
    let packed = Packer::new(hw, Scheme::Bitmask).pack(&fm, &division, true);
    let mut fetcher = Fetcher::new(&packed);
    let mut dram = Dram::default();
    let walker = TileWalker::new(layer, tile);
    for w in walker.iter() {
        let _ = fetcher.fetch_window(&mut dram, w.y0, w.y1, w.x0, w.x1, w.c0, w.c1);
    }

    // Metadata: both count one record per touched block per tile.
    assert_eq!(
        dram.words_of(Stream::MetadataRead),
        analytic.metadata_bits.div_ceil(16),
        "metadata accounting must match"
    );
    // Features: the analytic path line-rounds every sub-tensor; the
    // fetcher moves exact compressed spans. Analytic >= runtime and
    // within one line per sub-tensor fetch.
    let analytic_words = analytic.fetched_bits / 16;
    let runtime_words = dram.words_of(Stream::FeatureRead);
    assert!(analytic_words >= runtime_words);
    let rel = analytic_words as f64 / runtime_words as f64;
    assert!(rel < 1.30, "line rounding should be <30%: {rel}");
}

/// Packing must be lossless end-to-end for every mode and codec: fetch
/// the whole map back and compare (bf16-exact).
#[test]
fn pack_fetch_roundtrip_every_mode_and_codec() {
    let hw = Platform::NvidiaSmallTile.hardware();
    let layer = ConvLayer::new(1, 1, 21, 19, 12, 12);
    let fm = generate(21, 19, 12, SparsityParams::clustered(0.45, 8));
    let tile = hw.tile_for_layer(&layer);
    for mode in DivisionMode::table3_modes() {
        let Ok(division) = Division::build(mode, &layer, &tile, &hw, 21, 19, 12) else {
            continue; // mod 16 N/A on the small tile
        };
        for scheme in [Scheme::Bitmask, Scheme::Zrlc, Scheme::Dictionary, Scheme::Raw] {
            let packed = Packer::new(hw, scheme).pack(&fm, &division, true);
            let mut dram = Dram::default();
            let win = Fetcher::new(&packed).fetch_window(&mut dram, 0, 21, 0, 19, 0, 12);
            for y in 0..21 {
                for x in 0..19 {
                    for c in 0..12 {
                        assert_eq!(
                            win.get(y, x, c),
                            fm.get(y, x, c),
                            "{} {} ({y},{x},{c})",
                            mode.name(),
                            scheme.name()
                        );
                    }
                }
            }
        }
    }
}

/// The coordinator pipeline's feature traffic must equal the fetcher's
/// for the same schedule (it *is* the same code path), and its output
/// must match the dense oracle.
#[test]
fn pipeline_traffic_and_correctness() {
    let layer = ConvLayer::new(1, 1, 32, 32, 16, 8);
    let fm = generate(32, 32, 16, SparsityParams::clustered(0.4, 13));
    let w = Weights::random(&layer, 9);
    let mut cfg = PipelineConfig::new(Platform::NvidiaSmallTile.hardware());
    cfg.mode = DivisionMode::GrateTile { n: 8 };
    let runner = LayerRunner::new(cfg);
    let packed = runner.pack(&layer, &fm).unwrap();
    let (out, metrics) = runner.run_layer(&layer, &w, &packed).unwrap();

    let oracle = direct_conv_relu(&layer, &w, &fm);
    for (i, (&a, &b)) in out.as_slice().iter().zip(oracle.as_slice()).enumerate() {
        let scale = a.abs().max(b.abs()).max(1.0);
        assert!((a - b).abs() / scale < 0.02, "idx {i}: {a} vs {b}");
    }
    assert!(metrics.feature_lines > 0 && metrics.tiles == 4 * 2);
}

/// GrateTile's headline property, end to end: on a realistic layer the
/// grate store moves less data than every uniform store, and metadata
/// stays under 1% of the baseline.
#[test]
fn headline_property_end_to_end() {
    let hw = Platform::EyerissLargeTile.hardware();
    let layer = ConvLayer::new(1, 1, 56, 56, 64, 64);
    let fm = generate(56, 56, 64, SparsityParams::clustered(0.37, 4));
    let grate = run_layer(&hw, &layer, &fm, DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask)
        .unwrap();
    for edge in [2usize, 4, 8] {
        let uni = run_layer(&hw, &layer, &fm, DivisionMode::Uniform { edge }, Scheme::Bitmask)
            .unwrap();
        assert!(
            grate.saving_with_meta() > uni.saving_with_meta(),
            "grate {} vs uniform{edge} {}",
            grate.saving_with_meta(),
            uni.saving_with_meta()
        );
    }
    let meta_frac = grate.metadata_bits as f64 / grate.baseline_bits as f64;
    assert!(meta_frac < 0.02, "metadata fraction {meta_frac}");
}
