//! The acceptance tests of the store subsystem: a multi-layer pipeline
//! run keeps every intermediate compressed in the `TensorStore`, its
//! functional per-layer write-back bits equal the analytic simulator's
//! `writeback_cost` exactly, and a `.grate` container round-trips
//! (write → reopen → serve a window) bit-exactly.

use gratetile::compress::{CodecPolicy, Scheme};
use gratetile::config::hardware::Platform;
use gratetile::config::layer::ConvLayer;
use gratetile::coordinator::{LayerRunner, PipelineConfig, Weights};
use gratetile::memsim::Dram;
use gratetile::sim::network::writeback_cost;
use gratetile::store::{Container, TensorStore};
use gratetile::tensor::sparsity::{generate, SparsityParams};
use gratetile::tiling::division::DivisionMode;
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("gratetile-it-{name}-{}", std::process::id()));
    p
}

fn cfg(mode: DivisionMode, policy: impl Into<CodecPolicy>) -> PipelineConfig {
    let mut c = PipelineConfig::new(Platform::NvidiaSmallTile.hardware());
    c.mode = mode;
    c.policy = policy.into();
    c
}

/// THE exactness criterion: chain layers store-resident and, for every
/// layer, the streaming writer's (payload, metadata) bits must equal
/// `sim::network::writeback_cost` evaluated on the map it actually
/// wrote, under the division the next layer consumes it with.
#[test]
fn functional_writeback_matches_analytic_bit_exactly() {
    for (mode, scheme) in [
        (DivisionMode::GrateTile { n: 8 }, CodecPolicy::Fixed(Scheme::Bitmask)),
        (DivisionMode::GrateTile { n: 8 }, CodecPolicy::Fixed(Scheme::Zrlc)),
        (DivisionMode::GrateTile { n: 8 }, CodecPolicy::Adaptive),
        (DivisionMode::Uniform { edge: 4 }, CodecPolicy::Fixed(Scheme::Bitmask)),
        (DivisionMode::Uniform { edge: 4 }, CodecPolicy::Adaptive),
    ] {
        let l1 = ConvLayer::new(1, 1, 32, 32, 16, 16);
        let l2 = ConvLayer::new(1, 2, 32, 32, 16, 8);
        let layers = vec![(l1, Weights::random(&l1, 3)), (l2, Weights::random(&l2, 4))];
        let input = generate(32, 32, 16, SparsityParams::clustered(0.45, 11));
        let runner = LayerRunner::new(cfg(mode, scheme));
        let hw = runner.cfg.hw;

        let mut store = TensorStore::new();
        let per_layer = runner
            .run_network_in_store(&mut store, &layers, input, "act")
            .unwrap();

        // Layer 1's output (act1) was consumed and freed; recompute the
        // chain layer by layer to check each report against the
        // analytic cost of the map it wrote.
        let mut store2 = TensorStore::new();
        let input2 = generate(32, 32, 16, SparsityParams::clustered(0.45, 11));
        let packed = runner.pack(&layers[0].0, &input2).unwrap();
        store2.insert_packed("act0", &packed).unwrap();
        for (i, (layer, weights)) in layers.iter().enumerate() {
            let next = layers.get(i + 1).map(|(l, _)| l);
            let div = runner
                .output_division(next, layer.out_h(), layer.out_w(), layer.c_out)
                .unwrap();
            let out_mode = div.mode;
            let m = runner
                .run_layer_store(
                    &mut store2,
                    &format!("act{i}"),
                    &format!("act{}", i + 1),
                    layer,
                    weights,
                    div,
                )
                .unwrap();
            // The map the writer actually stored, fetched back dense.
            let mut dram = Dram::default();
            let written = store2.fetch_dense(&format!("act{}", i + 1), &mut dram).unwrap();
            // The analytic producer model on that same map, under the
            // same consumer division.
            // Same identity-view fallback `output_division` uses when
            // the stack ends.
            let consumer = next.copied().unwrap_or(ConvLayer::new(
                0,
                1,
                layer.out_h(),
                layer.out_w(),
                layer.c_out,
                layer.c_out,
            ));
            let (payload, meta) =
                writeback_cost(&hw, &consumer, &written, out_mode, scheme).unwrap();
            assert_eq!(
                m.writeback_payload_bits, payload,
                "layer {i} payload bits ({mode:?}, {scheme:?})"
            );
            assert_eq!(
                m.writeback_meta_bits, meta,
                "layer {i} metadata bits ({mode:?}, {scheme:?})"
            );
            // And the whole-chain run reported the same numbers.
            assert_eq!(per_layer[i].writeback_payload_bits, payload);
            assert_eq!(per_layer[i].writeback_meta_bits, meta);
        }
    }
}

/// Container round trip at the serving boundary: run a network, export
/// the store-resident result into a `.grate` file, reopen it, and serve
/// windows off the file — bit-exact against the in-store tensor.
#[test]
fn container_serves_store_resident_result_bit_exactly() {
    let l1 = ConvLayer::new(1, 1, 24, 24, 8, 16);
    let layers = vec![(l1, Weights::random(&l1, 9))];
    let input = generate(24, 24, 8, SparsityParams::clustered(0.5, 13));
    let runner = LayerRunner::new(cfg(DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask));
    let mut store = TensorStore::new();
    runner.run_network_in_store(&mut store, &layers, input, "act").unwrap();

    let mut dram = Dram::default();
    let resident = store.fetch_dense("act1", &mut dram).unwrap();

    let path = tmp("serve-window.grate");
    let exported = store.export("act1").unwrap();
    Container::write(&path, &[("act1".to_string(), &exported)]).unwrap();

    let c = Container::open(&path).unwrap();
    c.verify().unwrap();
    // Serve a partial window straight off the file.
    let win = c.fetch_window("act1", &mut dram, 5, 19, 2, 23, 3, 13).unwrap();
    for y in 5..19 {
        for x in 2..23 {
            for ch in 3..13 {
                assert_eq!(win.get(y, x, ch), resident.get(y, x, ch), "({y},{x},{ch})");
            }
        }
    }
    // And the whole map.
    let dense = c.fetch_dense("act1", &mut dram).unwrap();
    assert_eq!(dense.as_slice(), resident.as_slice());
    std::fs::remove_file(&path).ok();
}

/// The timed-DRAM replay sees distinct, scattered store addresses: two
/// different resident tensors never produce identical access traces.
#[test]
fn store_addresses_are_real() {
    let l1 = ConvLayer::new(1, 1, 24, 24, 8, 8);
    let l2 = ConvLayer::new(1, 1, 24, 24, 8, 8);
    let layers = vec![(l1, Weights::random(&l1, 1)), (l2, Weights::random(&l2, 2))];
    let input = generate(24, 24, 8, SparsityParams::clustered(0.5, 3));
    let runner = LayerRunner::new(cfg(DivisionMode::GrateTile { n: 8 }, Scheme::Bitmask));
    let mut store = TensorStore::new();
    let per_layer = runner
        .run_network_in_store(&mut store, &layers, input, "act")
        .unwrap();
    for m in &per_layer {
        assert!(m.dram_cycles > 0);
        assert!(m.row_hits + m.row_misses > 0);
    }
    // Layer 2 read act1, which the arena placed *after* act0 — its
    // fetch touched high addresses, which only a real address space
    // can produce. The store's final tensor sits at a nonzero base.
    let t = store.get("act2").unwrap();
    assert!(t.extents.iter().any(|&(base, _)| base > 0));
    store.arena().check().unwrap();
}
