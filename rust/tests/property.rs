//! Randomised cross-module property tests (proptest_lite): the
//! invariants that must hold for *any* layer geometry, sparsity and
//! division mode, not just the benchmark configurations.

use gratetile::compress::{Compressor, Scheme};
use gratetile::config::hardware::Platform;
use gratetile::config::layer::ConvLayer;
use gratetile::layout::{Fetcher, Packer};
use gratetile::memsim::Dram;
use gratetile::sim::experiment::{run_layer, run_layer_naive};
use gratetile::tensor::sparsity::{generate, SparsityParams};
use gratetile::tiling::division::{Division, DivisionMode};
use gratetile::util::proptest_lite::forall_res;
use gratetile::util::SplitMix64;

/// Random layer + mode + density scenario.
#[derive(Debug, Clone)]
struct Scenario {
    layer: ConvLayer,
    mode: DivisionMode,
    scheme: Scheme,
    density: f64,
    seed: u64,
}

fn gen_scenario(r: &mut SplitMix64) -> Scenario {
    let k = r.below(3); // kernels 1/3/5
    let s = 1 + r.below(2);
    let d = if k > 0 && r.chance(0.2) { 2 } else { 1 };
    let h = 9 + r.below(40);
    let w = 9 + r.below(40);
    let c = 8 * (1 + r.below(4));
    let mode = match r.below(6) {
        0 => DivisionMode::GrateTile { n: 4 },
        1 | 2 => DivisionMode::GrateTile { n: 8 },
        3 => DivisionMode::Uniform { edge: 8 },
        4 => DivisionMode::Uniform { edge: 4 },
        _ => DivisionMode::Uniform { edge: 1 },
    };
    let scheme = match r.below(3) {
        0 => Scheme::Bitmask,
        1 => Scheme::Zrlc,
        _ => Scheme::Dictionary,
    };
    Scenario {
        layer: ConvLayer { k, s, d, h, w, c_in: c, c_out: c },
        mode,
        scheme,
        density: r.next_f64(),
        seed: r.next_u64(),
    }
}

/// Lossless storage: packing then fetching the whole map returns the
/// exact bf16 feature map, for every (geometry, mode, codec, density).
#[test]
fn prop_pack_fetch_lossless() {
    forall_res(0xFE7C, 60, gen_scenario, |sc| {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (h, w, c) = (sc.layer.h, sc.layer.w, sc.layer.c_in);
        let tile = hw.tile_for_layer(&sc.layer);
        let division = match Division::build(sc.mode, &sc.layer, &tile, &hw, h, w, c) {
            Ok(d) => d,
            Err(_) => return Ok(()), // N/A combinations are fine
        };
        let fm = generate(h, w, c, SparsityParams::clustered(sc.density, sc.seed));
        let packed = Packer::new(hw, sc.scheme).pack(&fm, &division, true);
        let mut dram = Dram::default();
        let win = Fetcher::new(&packed).fetch_window(&mut dram, 0, h, 0, w, 0, c);
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    if win.get(y, x, ch) != fm.get(y, x, ch) {
                        return Err(format!(
                            "mismatch at ({y},{x},{ch}) mode={} scheme={}",
                            sc.mode.name(),
                            sc.scheme.name()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Division completeness: sub-tensor word counts sum to the map size,
/// and every sub-tensor belongs to exactly one metadata block.
#[test]
fn prop_division_partitions_map() {
    forall_res(0xD117, 120, gen_scenario, |sc| {
        let hw = Platform::EyerissLargeTile.hardware();
        let (h, w, c) = (sc.layer.h, sc.layer.w, sc.layer.c_in);
        let tile = hw.tile_for_layer(&sc.layer);
        let division = match Division::build(sc.mode, &sc.layer, &tile, &hw, h, w, c) {
            Ok(d) => d,
            Err(_) => return Ok(()),
        };
        let mut total = 0usize;
        for iy in 0..division.ys.len() {
            for ix in 0..division.xs.len() {
                for icg in 0..division.n_cgroups {
                    let r = gratetile::tiling::division::SubTensorRef { iy, ix, icg };
                    total += division.subtensor_words(r);
                    let b = division.block_linear(r);
                    if b >= division.n_blocks() {
                        return Err(format!("block id {b} out of range"));
                    }
                }
            }
        }
        if total != h * w * c {
            return Err(format!("partition covers {total} of {}", h * w * c));
        }
        Ok(())
    });
}

/// The prefix-sum pricer is the production pricing path; the naive
/// per-sub-tensor walker is the reference oracle. They must agree
/// bit-exactly — fetched, metadata AND baseline bits — for every random
/// layer geometry (strides, dilation, ragged maps), density, platform,
/// and every Table III division mode.
#[test]
fn prop_pricer_matches_naive_walker() {
    forall_res(0x9A1C, 25, gen_scenario, |sc| {
        let (h, w, c) = (sc.layer.h, sc.layer.w, sc.layer.c_in);
        let fm = generate(h, w, c, SparsityParams::clustered(sc.density, sc.seed));
        for platform in [Platform::NvidiaSmallTile, Platform::EyerissLargeTile] {
            let hw = platform.hardware();
            for mode in DivisionMode::table3_modes() {
                let fast = run_layer(&hw, &sc.layer, &fm, mode, sc.scheme);
                let slow = run_layer_naive(&hw, &sc.layer, &fm, mode, sc.scheme);
                match (fast, slow) {
                    (Ok(f), Ok(s)) => {
                        if (f.fetched_bits, f.metadata_bits, f.baseline_bits)
                            != (s.fetched_bits, s.metadata_bits, s.baseline_bits)
                        {
                            return Err(format!(
                                "{} {}: pricer ({}, {}, {}) != naive ({}, {}, {})",
                                hw.name,
                                mode.name(),
                                f.fetched_bits,
                                f.metadata_bits,
                                f.baseline_bits,
                                s.fetched_bits,
                                s.metadata_bits,
                                s.baseline_bits,
                            ));
                        }
                    }
                    (Err(a), Err(b)) if a == b => {}
                    (f, s) => {
                        return Err(format!(
                            "{} {}: applicability mismatch {f:?} vs {s:?}",
                            hw.name,
                            mode.name()
                        ))
                    }
                }
            }
        }
        Ok(())
    });
}

/// Bandwidth sanity for every scenario: fetched >= information content
/// (can't beat the nonzeros), saving <= optimal + epsilon for sparse
/// codecs, and metadata strictly positive.
#[test]
fn prop_bandwidth_bounds() {
    forall_res(0xBA4D, 40, gen_scenario, |sc| {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (h, w, c) = (sc.layer.h, sc.layer.w, sc.layer.c_in);
        let fm = generate(h, w, c, SparsityParams::clustered(sc.density, sc.seed));
        let r = match run_layer(&hw, &sc.layer, &fm, sc.mode, sc.scheme) {
            Ok(r) => r,
            Err(_) => return Ok(()),
        };
        if r.baseline_bits == 0 {
            return Err("empty baseline".into());
        }
        if r.metadata_bits == 0 {
            return Err("metadata must be accounted".into());
        }
        // A window's fetch can't be smaller than its nonzero payload
        // (bitmask/zrlc/dict all store nonzeros verbatim at >= 16 bits).
        if sc.scheme == Scheme::Bitmask {
            let floor = (r.baseline_bits as f64) * fm.density() * 0.95;
            if (r.fetched_bits as f64) < floor {
                return Err(format!(
                    "fetched {} below information floor {floor}",
                    r.fetched_bits
                ));
            }
        }
        Ok(())
    });
}

/// Codec round-trips on adversarial payloads: long runs, alternating
/// patterns, denormals, negative zero, all-dense.
#[test]
fn codec_adversarial_payloads() {
    let patterns: Vec<Vec<f32>> = vec![
        vec![0.0; 1024],
        vec![1.0; 1024],
        (0..1024).map(|i| if i % 2 == 0 { 0.0 } else { 1.5 }).collect(),
        (0..1024).map(|i| if i % 33 == 0 { -2.5 } else { 0.0 }).collect(),
        (0..1024)
            .map(|i| if i < 512 { 0.0 } else { (i as f32 - 700.0) * 1e-3 })
            .collect(),
        vec![-0.0; 64], // negative zero is a zero
        (0..97).map(|i| (i as f32) * 1e30).collect(), // big magnitudes
        (0..97).map(|i| (i as f32) * 1e-30).collect(), // tiny magnitudes
    ];
    for scheme in [Scheme::Bitmask, Scheme::Zrlc, Scheme::Dictionary, Scheme::Raw] {
        let codec = scheme.build();
        for (pi, p) in patterns.iter().enumerate() {
            let quant: Vec<f32> =
                p.iter().map(|&x| gratetile::tensor::dense::bf16_quantise(x)).collect();
            let comp = codec.compress(&quant);
            let mut out = vec![9.0f32; quant.len()];
            codec.decompress(&comp, &mut out);
            // -0.0 compresses as a zero; compare with == (true for ±0).
            assert_eq!(out, quant, "{} pattern {pi}", scheme.name());
            assert_eq!(
                comp.compressed_words(),
                codec.compressed_words(&quant),
                "{} pattern {pi} size fast path",
                scheme.name()
            );
        }
    }
}

/// The mod-reduction property at the full-division level: a mod-4
/// GrateTile division's cut set contains the mod-8 division's cuts
/// (N′ | N ⇒ more cuts, never fewer).
#[test]
fn prop_mod_reduction_refines_cuts() {
    forall_res(0x04EF, 80, |r: &mut SplitMix64| {
        let k = r.below(3);
        let s = 1 + r.below(2);
        (k, s, 16 + r.below(48))
    }, |&(k, s, len)| {
        let layer = ConvLayer::new(k, s, 224, 224, 64, 64);
        let hw = Platform::EyerissLargeTile.hardware();
        let tile = hw.tile_for_layer(&layer);
        let d8 = Division::build(DivisionMode::GrateTile { n: 8 }, &layer, &tile, &hw, len, len, 8);
        let d4 = Division::build(DivisionMode::GrateTile { n: 4 }, &layer, &tile, &hw, len, len, 8);
        let (Ok(d8), Ok(d4)) = (d8, d4) else { return Ok(()) };
        let cuts = |d: &Division| -> Vec<usize> {
            d.ys.iter().skip(1).map(|s| s.start).collect()
        };
        let c8 = cuts(&d8);
        let c4 = cuts(&d4);
        for c in &c8 {
            if !c4.contains(c) {
                return Err(format!("mod-4 misses mod-8 cut {c} (k={k},s={s},len={len})"));
            }
        }
        Ok(())
    });
}
