//! Randomised cross-module property tests (proptest_lite): the
//! invariants that must hold for *any* layer geometry, sparsity and
//! division mode, not just the benchmark configurations.

use gratetile::compress::{CodecPolicy, Compressor, Registry, Scheme};
use gratetile::compute::{GemmBackend, SkipPolicy};
use gratetile::config::hardware::Platform;
use gratetile::config::layer::ConvLayer;
use gratetile::coordinator::conv::{direct_conv_relu, Weights};
use gratetile::layout::{Fetcher, Packer};
use gratetile::memsim::{Dram, DramTiming, SharedDram};
use gratetile::sim::experiment::{run_layer, run_layer_naive};
use gratetile::sim::{metadata_cache_study, TileOrder};
use gratetile::store::{Arena, Container, StoreWriter, TensorStore};
use gratetile::tensor::sparsity::{generate, SparsityParams};
use gratetile::tiling::division::{Division, DivisionMode};
use gratetile::util::proptest_lite::forall_res;
use gratetile::util::SplitMix64;

/// Random layer + mode + density scenario.
#[derive(Debug, Clone)]
struct Scenario {
    layer: ConvLayer,
    mode: DivisionMode,
    policy: CodecPolicy,
    density: f64,
    seed: u64,
}

fn gen_scenario(r: &mut SplitMix64) -> Scenario {
    let k = r.below(3); // kernels 1/3/5
    let s = 1 + r.below(2);
    let d = if k > 0 && r.chance(0.2) { 2 } else { 1 };
    let h = 9 + r.below(40);
    let w = 9 + r.below(40);
    let c = 8 * (1 + r.below(4));
    let mode = match r.below(6) {
        0 => DivisionMode::GrateTile { n: 4 },
        1 | 2 => DivisionMode::GrateTile { n: 8 },
        3 => DivisionMode::Uniform { edge: 8 },
        4 => DivisionMode::Uniform { edge: 4 },
        _ => DivisionMode::Uniform { edge: 1 },
    };
    let policy = match r.below(5) {
        0 => CodecPolicy::Fixed(Scheme::Bitmask),
        1 => CodecPolicy::Fixed(Scheme::Zrlc),
        2 => CodecPolicy::Fixed(Scheme::Dictionary),
        3 => CodecPolicy::Fixed(Scheme::Raw),
        _ => CodecPolicy::Adaptive,
    };
    Scenario {
        layer: ConvLayer { k, s, d, h, w, c_in: c, c_out: c },
        mode,
        policy,
        density: r.next_f64(),
        seed: r.next_u64(),
    }
}

/// The plan/execute packing engine is bit-exact with the seed packer
/// (kept as `pack_reference`) for every (geometry, mode, codec,
/// density): sizes, idealised bits, addresses, metadata records, total
/// footprint AND the payload bytes.
#[test]
fn prop_engine_matches_seed_packer() {
    forall_res(0xEC0DE, 40, gen_scenario, |sc| {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (h, w, c) = (sc.layer.h, sc.layer.w, sc.layer.c_in);
        let tile = hw.tile_for_layer(&sc.layer);
        let division = match Division::build(sc.mode, &sc.layer, &tile, &hw, h, w, c) {
            Ok(d) => d,
            Err(_) => return Ok(()),
        };
        let fm = generate(h, w, c, SparsityParams::clustered(sc.density, sc.seed));
        let packer = Packer::new(hw, sc.policy);
        let oracle = packer.pack_reference(&fm, &division, true);
        let engine = packer.pack(&fm, &division, true);
        let tag = format!("{} {}", sc.mode.name(), sc.policy.name());
        if oracle.sizes_words != engine.sizes_words {
            return Err(format!("{tag}: sizes_words diverge"));
        }
        if oracle.sizes_bits != engine.sizes_bits {
            return Err(format!("{tag}: sizes_bits diverge"));
        }
        if oracle.tags != engine.tags {
            return Err(format!("{tag}: codec tags diverge"));
        }
        if oracle.addr_words != engine.addr_words {
            return Err(format!("{tag}: addr_words diverge"));
        }
        if oracle.total_words != engine.total_words {
            return Err(format!("{tag}: total_words diverge"));
        }
        if oracle.payload != engine.payload {
            return Err(format!("{tag}: payload bytes diverge"));
        }
        if oracle.checksums != engine.checksums {
            return Err(format!("{tag}: integrity checksums diverge"));
        }
        if oracle.metadata.records.len() != engine.metadata.records.len() {
            return Err(format!("{tag}: record counts diverge"));
        }
        for (i, (a, b)) in
            oracle.metadata.records.iter().zip(&engine.metadata.records).enumerate()
        {
            if a.pointer_words != b.pointer_words || a.sizes_words != b.sizes_words {
                return Err(format!("{tag}: record {i} diverges"));
            }
        }
        Ok(())
    });
}

/// Packing is deterministic in the worker count: `--jobs 1/2/8`
/// produce byte-identical packs (the engine writes into planned
/// disjoint slices, so scheduling cannot reorder anything). Uses a map
/// large enough to actually engage the parallel path.
#[test]
fn prop_pack_deterministic_across_jobs() {
    use gratetile::util::parallel::set_threads;
    let hw = Platform::NvidiaSmallTile.hardware();
    let layer = ConvLayer::new(1, 1, 64, 64, 32, 32);
    let tile = hw.tile_for_layer(&layer);
    let fm = generate(64, 64, 32, SparsityParams::clustered(0.4, 77));
    for mode in [DivisionMode::GrateTile { n: 8 }, DivisionMode::Uniform { edge: 1 }] {
        let division = Division::build(mode, &layer, &tile, &hw, 64, 64, 32).unwrap();
        for scheme in [
            CodecPolicy::Fixed(Scheme::Bitmask),
            CodecPolicy::Fixed(Scheme::Zrlc),
            CodecPolicy::Fixed(Scheme::Dictionary),
            CodecPolicy::Adaptive,
        ] {
            let packer = Packer::new(hw, scheme);
            set_threads(1);
            let one = packer.pack(&fm, &division, true);
            let mut packs = Vec::new();
            for jobs in [2usize, 8] {
                set_threads(jobs);
                packs.push((jobs, packer.pack(&fm, &division, true)));
            }
            set_threads(0);
            for (jobs, p) in &packs {
                assert_eq!(p.tags, one.tags, "{mode:?} {scheme:?} jobs {jobs}");
                assert_eq!(p.sizes_words, one.sizes_words, "{mode:?} {scheme:?} jobs {jobs}");
                assert_eq!(p.sizes_bits, one.sizes_bits, "{mode:?} {scheme:?} jobs {jobs}");
                assert_eq!(p.addr_words, one.addr_words, "{mode:?} {scheme:?} jobs {jobs}");
                assert_eq!(p.payload, one.payload, "{mode:?} {scheme:?} jobs {jobs}");
                assert_eq!(p.total_words, one.total_words, "{mode:?} {scheme:?} jobs {jobs}");
            }
        }
    }
}

/// The fetcher's software fast paths (decoded-sub-tensor LRU, popcount
/// row-skipped partial decode) never change what a window contains or
/// what traffic the simulator accounts: cache-on and cache-off reads
/// are identical in data AND in DRAM words, for random windows over
/// random scenarios.
#[test]
fn prop_fetch_lru_and_span_invariant() {
    forall_res(0xCACE, 30, gen_scenario, |sc| {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (h, w, c) = (sc.layer.h, sc.layer.w, sc.layer.c_in);
        let tile = hw.tile_for_layer(&sc.layer);
        let division = match Division::build(sc.mode, &sc.layer, &tile, &hw, h, w, c) {
            Ok(d) => d,
            Err(_) => return Ok(()),
        };
        let fm = generate(h, w, c, SparsityParams::clustered(sc.density, sc.seed));
        let packed = Packer::new(hw, sc.policy).pack(&fm, &division, true);
        let mut plain = Fetcher::new(&packed);
        let mut cached = Fetcher::new(&packed).with_cache(8);
        let mut d_plain = Dram::default();
        let mut d_cached = Dram::default();
        let mut rng = SplitMix64::new(sc.seed ^ 0xFA57);
        for _ in 0..6 {
            let y0 = rng.below(h);
            let y1 = (y0 + 1 + rng.below(h - y0)).min(h);
            let x0 = rng.below(w);
            let x1 = (x0 + 1 + rng.below(w - x0)).min(w);
            let a = plain.fetch_window(&mut d_plain, y0, y1, x0, x1, 0, c);
            let b = cached.fetch_window(&mut d_cached, y0, y1, x0, x1, 0, c);
            if a != b {
                return Err(format!(
                    "window ({y0},{y1})x({x0},{x1}) differs with LRU on ({} {})",
                    sc.mode.name(),
                    sc.policy.name()
                ));
            }
            // Ground truth: the dense map.
            for y in y0..y1 {
                for x in x0..x1 {
                    for ch in 0..c {
                        if a.get(y, x, ch) != fm.get(y, x, ch) {
                            return Err(format!(
                                "mismatch vs dense at ({y},{x},{ch}) ({} {})",
                                sc.mode.name(),
                                sc.policy.name()
                            ));
                        }
                    }
                }
            }
        }
        use gratetile::memsim::Stream;
        for s in [Stream::FeatureRead, Stream::MetadataRead] {
            if d_plain.words_of(s) != d_cached.words_of(s) {
                return Err(format!(
                    "{s:?} traffic diverges with LRU on: {} vs {}",
                    d_plain.words_of(s),
                    d_cached.words_of(s)
                ));
            }
        }
        Ok(())
    });
}

/// Lossless storage: packing then fetching the whole map returns the
/// exact bf16 feature map, for every (geometry, mode, codec, density).
#[test]
fn prop_pack_fetch_lossless() {
    forall_res(0xFE7C, 60, gen_scenario, |sc| {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (h, w, c) = (sc.layer.h, sc.layer.w, sc.layer.c_in);
        let tile = hw.tile_for_layer(&sc.layer);
        let division = match Division::build(sc.mode, &sc.layer, &tile, &hw, h, w, c) {
            Ok(d) => d,
            Err(_) => return Ok(()), // N/A combinations are fine
        };
        let fm = generate(h, w, c, SparsityParams::clustered(sc.density, sc.seed));
        let packed = Packer::new(hw, sc.policy).pack(&fm, &division, true);
        let mut dram = Dram::default();
        let win = Fetcher::new(&packed).fetch_window(&mut dram, 0, h, 0, w, 0, c);
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    if win.get(y, x, ch) != fm.get(y, x, ch) {
                        return Err(format!(
                            "mismatch at ({y},{x},{ch}) mode={} scheme={}",
                            sc.mode.name(),
                            sc.policy.name()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Division completeness: sub-tensor word counts sum to the map size,
/// and every sub-tensor belongs to exactly one metadata block.
#[test]
fn prop_division_partitions_map() {
    forall_res(0xD117, 120, gen_scenario, |sc| {
        let hw = Platform::EyerissLargeTile.hardware();
        let (h, w, c) = (sc.layer.h, sc.layer.w, sc.layer.c_in);
        let tile = hw.tile_for_layer(&sc.layer);
        let division = match Division::build(sc.mode, &sc.layer, &tile, &hw, h, w, c) {
            Ok(d) => d,
            Err(_) => return Ok(()),
        };
        let mut total = 0usize;
        for iy in 0..division.ys.len() {
            for ix in 0..division.xs.len() {
                for icg in 0..division.n_cgroups {
                    let r = gratetile::tiling::division::SubTensorRef { iy, ix, icg };
                    total += division.subtensor_words(r);
                    let b = division.block_linear(r);
                    if b >= division.n_blocks() {
                        return Err(format!("block id {b} out of range"));
                    }
                }
            }
        }
        if total != h * w * c {
            return Err(format!("partition covers {total} of {}", h * w * c));
        }
        Ok(())
    });
}

/// The prefix-sum pricer is the production pricing path; the naive
/// per-sub-tensor walker is the reference oracle. They must agree
/// bit-exactly — fetched, metadata AND baseline bits — for every random
/// layer geometry (strides, dilation, ragged maps), density, platform,
/// and every Table III division mode.
#[test]
fn prop_pricer_matches_naive_walker() {
    forall_res(0x9A1C, 25, gen_scenario, |sc| {
        let (h, w, c) = (sc.layer.h, sc.layer.w, sc.layer.c_in);
        let fm = generate(h, w, c, SparsityParams::clustered(sc.density, sc.seed));
        for platform in [Platform::NvidiaSmallTile, Platform::EyerissLargeTile] {
            let hw = platform.hardware();
            for mode in DivisionMode::table3_modes() {
                let fast = run_layer(&hw, &sc.layer, &fm, mode, sc.policy);
                let slow = run_layer_naive(&hw, &sc.layer, &fm, mode, sc.policy);
                match (fast, slow) {
                    (Ok(f), Ok(s)) => {
                        if (f.fetched_bits, f.metadata_bits, f.baseline_bits)
                            != (s.fetched_bits, s.metadata_bits, s.baseline_bits)
                        {
                            return Err(format!(
                                "{} {}: pricer ({}, {}, {}) != naive ({}, {}, {})",
                                hw.name,
                                mode.name(),
                                f.fetched_bits,
                                f.metadata_bits,
                                f.baseline_bits,
                                s.fetched_bits,
                                s.metadata_bits,
                                s.baseline_bits,
                            ));
                        }
                    }
                    (Err(a), Err(b)) if a == b => {}
                    (f, s) => {
                        return Err(format!(
                            "{} {}: applicability mismatch {f:?} vs {s:?}",
                            hw.name,
                            mode.name()
                        ))
                    }
                }
            }
        }
        Ok(())
    });
}

/// Bandwidth sanity for every scenario: fetched >= information content
/// (can't beat the nonzeros), saving <= optimal + epsilon for sparse
/// codecs, and metadata strictly positive.
#[test]
fn prop_bandwidth_bounds() {
    forall_res(0xBA4D, 40, gen_scenario, |sc| {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (h, w, c) = (sc.layer.h, sc.layer.w, sc.layer.c_in);
        let fm = generate(h, w, c, SparsityParams::clustered(sc.density, sc.seed));
        let r = match run_layer(&hw, &sc.layer, &fm, sc.mode, sc.policy) {
            Ok(r) => r,
            Err(_) => return Ok(()),
        };
        if r.baseline_bits == 0 {
            return Err("empty baseline".into());
        }
        if r.metadata_bits == 0 {
            return Err("metadata must be accounted".into());
        }
        // A window's fetch can't be smaller than its nonzero payload
        // (bitmask/zrlc/dict all store nonzeros verbatim at >= 16 bits).
        if sc.policy == CodecPolicy::Fixed(Scheme::Bitmask) {
            let floor = (r.baseline_bits as f64) * fm.density() * 0.95;
            if (r.fetched_bits as f64) < floor {
                return Err(format!(
                    "fetched {} below information floor {floor}",
                    r.fetched_bits
                ));
            }
        }
        Ok(())
    });
}

/// The full storage chain round-trips bit-exactly for every (geometry,
/// division mode, codec, density): pack → store write (streamed in
/// randomized tile bands) → container serialize → reopen →
/// `fetch_window` against the dense reference, across all Table III
/// modes, ragged shapes and all four codecs.
#[test]
fn prop_store_container_roundtrip() {
    forall_res(0x570E, 18, gen_scenario, |sc| {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (h, w, c) = (sc.layer.h, sc.layer.w, sc.layer.c_in);
        let tile = hw.tile_for_layer(&sc.layer);
        let division = match Division::build(sc.mode, &sc.layer, &tile, &hw, h, w, c) {
            Ok(d) => d,
            Err(_) => return Ok(()), // N/A combinations are fine
        };
        let fm = generate(h, w, c, SparsityParams::clustered(sc.density, sc.seed));

        // Stream the map into a store in bands whose height depends on
        // the seed (exercises partial sub-tensor staging).
        let mut store = TensorStore::new();
        let mut writer = StoreWriter::new(&mut store, "t", division, sc.policy);
        let band = 1 + (sc.seed % 11) as usize;
        let mut y0 = 0;
        while y0 < h {
            let y1 = (y0 + band).min(h);
            let data = fm.extract_block(y0, 0, 0, y1 - y0, w, c);
            writer.write_tile(y0, y1, 0, w, 0, c, &data);
            y0 = y1;
        }
        let report = writer.finish().map_err(|e| e.to_string())?;
        if report.subtensors == 0 {
            return Err("empty division".into());
        }
        store.arena().check()?;

        // Serialize, reopen, fetch a random window off the file.
        let exported = store.export("t").map_err(|e| e.to_string())?;
        let mut path = std::env::temp_dir();
        path.push(format!("gratetile-prop-{}-{}.grate", std::process::id(), sc.seed));
        Container::write(&path, &[("t".to_string(), &exported)])
            .map_err(|e| e.to_string())?;
        let cont = Container::open(&path).map_err(|e| e.to_string())?;
        let mut rng = SplitMix64::new(sc.seed ^ 0xC0);
        let (wy0, wy1) = {
            let a = rng.below(h);
            (a, (a + 1 + rng.below(h - a)).min(h))
        };
        let (wx0, wx1) = {
            let a = rng.below(w);
            (a, (a + 1 + rng.below(w - a)).min(w))
        };
        let mut dram = Dram::default();
        let win = cont
            .fetch_window("t", &mut dram, wy0, wy1, wx0, wx1, 0, c)
            .map_err(|e| e.to_string())?;
        std::fs::remove_file(&path).ok();
        for y in wy0..wy1 {
            for x in wx0..wx1 {
                for ch in 0..c {
                    if win.get(y, x, ch) != fm.get(y, x, ch) {
                        return Err(format!(
                            "container mismatch at ({y},{x},{ch}) mode={} scheme={}",
                            sc.mode.name(),
                            sc.policy.name()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// ISSUE 8 satellite: a randomly truncated or bit-flipped `.grate`
/// file must never panic on open or fetch. Every structural violation
/// is a typed error (bad magic, short TOC, checksum mismatch, short
/// payload); payload-only corruption decodes to garbage data, never a
/// crash — the decoders are corruption-tolerant by contract.
#[test]
fn prop_corrupt_container_never_panics() {
    forall_res(0xFA17, 6, gen_scenario, |sc| {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (h, w, c) = (sc.layer.h, sc.layer.w, sc.layer.c_in);
        let tile = hw.tile_for_layer(&sc.layer);
        let division = match Division::build(sc.mode, &sc.layer, &tile, &hw, h, w, c) {
            Ok(d) => d,
            Err(_) => return Ok(()),
        };
        let fm = generate(h, w, c, SparsityParams::clustered(sc.density.max(0.2), sc.seed));
        let mut store = TensorStore::new();
        let mut writer = StoreWriter::new(&mut store, "t", division, sc.policy);
        let data = fm.extract_block(0, 0, 0, h, w, c);
        writer.write_tile(0, h, 0, w, 0, c, &data);
        writer.finish().map_err(|e| e.to_string())?;
        let exported = store.export("t").map_err(|e| e.to_string())?;
        let mut path = std::env::temp_dir();
        path.push(format!("gratetile-chaos-{}-{}.grate", std::process::id(), sc.seed));
        Container::write(&path, &[("t".to_string(), &exported)])
            .map_err(|e| e.to_string())?;
        let pristine = std::fs::read(&path).map_err(|e| e.to_string())?;
        std::fs::remove_file(&path).ok();

        let mut rng = SplitMix64::new(sc.seed ^ 0xBAD);
        let mut mangled = path.clone();
        mangled.set_extension("mangled.grate");
        for trial in 0..24 {
            let mut bytes = pristine.clone();
            if trial % 2 == 0 {
                // Truncate at a random offset (including inside the
                // header, the TOC and the payload region).
                bytes.truncate(rng.below(bytes.len() + 1));
            } else {
                // Flip a random bit anywhere in the file.
                let at = rng.below(bytes.len());
                bytes[at] ^= 1 << rng.below(8);
            }
            std::fs::write(&mangled, &bytes).map_err(|e| e.to_string())?;
            // Every call below must return (Ok or Err) — never panic.
            if let Ok(cont) = Container::open(&mangled) {
                let mut dram = Dram::default();
                let _ = cont.fetch_window("t", &mut dram, 0, h, 0, w, 0, c);
                let _ = cont.read_tensor("t");
                let _ = cont.verify();
            }
        }
        std::fs::remove_file(&mangled).ok();
        Ok(())
    });
}

/// ISSUE 8 acceptance: chaos runs are deterministic in the host worker
/// count. With payload faults, integrity retries, deadlines and
/// shedding ALL active, the same seed renders byte-identical serving
/// reports across `--jobs` ∈ {1, 2, 8} — fault decisions are pure
/// hashes of (plan seed, site, request, address), never of scheduling.
#[test]
fn prop_chaos_report_deterministic_across_jobs() {
    use gratetile::coordinator::simserver::{ServingPolicy, SimServer, SimServerConfig};
    use gratetile::coordinator::PipelineConfig;
    use gratetile::fault::FaultPlan;
    use gratetile::layout::IntegrityPolicy;
    use gratetile::util::parallel::set_threads;
    let l1 = ConvLayer::new(1, 1, 16, 16, 8, 8);
    let l2 = ConvLayer::new(1, 2, 16, 16, 8, 8);
    let layers = vec![(l1, Weights::random(&l1, 1)), (l2, Weights::random(&l2, 2))];
    let mut cfg = SimServerConfig::new(PipelineConfig::new(Platform::NvidiaSmallTile.hardware()));
    cfg.pipeline.fault = Some(FaultPlan::uniform(41, 0.3));
    cfg.pipeline.integrity = Some(IntegrityPolicy::default());
    cfg.serving = ServingPolicy {
        deadline_cycles: 30_000_000,
        retry_budget: 1,
        shed_batch_on_overload: true,
        waiting_depth: 0,
    };
    let server = SimServer::new(cfg, layers);
    let reqs = server.synthetic_requests(8, 0.45, 21);
    let mut renders = Vec::new();
    for jobs in [1usize, 2, 8] {
        set_threads(jobs);
        let report = server.serve(reqs.clone()).unwrap();
        renders.push((jobs, report.render()));
    }
    set_threads(0);
    for (jobs, r) in &renders[1..] {
        assert_eq!(
            r, &renders[0].1,
            "chaos report bytes diverge between --jobs 1 and --jobs {jobs}"
        );
    }
}

/// Arena invariants under randomized size churn: line alignment, no
/// overlap, exact accounting, coalescing — through alloc/free/realloc
/// storms with skewed size distributions.
#[test]
fn prop_arena_invariants_under_churn() {
    forall_res(0xA11C, 40, |r: &mut SplitMix64| r.next_u64(), |&seed| {
        let mut rng = SplitMix64::new(seed);
        let mut arena = Arena::new(8);
        let mut live: Vec<(u64, u64)> = Vec::new(); // (addr, requested words)
        for step in 0..300 {
            let roll = rng.next_f64();
            if live.is_empty() || roll < 0.5 {
                let words = 1 + rng.below(500) as u64;
                let addr = arena.alloc(words);
                if addr % 8 != 0 {
                    return Err(format!("step {step}: unaligned alloc at {addr}"));
                }
                // No overlap with any live extent (by requested size).
                for &(a, l) in &live {
                    let l = l.div_ceil(8) * 8;
                    if addr < a + l && a < addr + words.div_ceil(8) * 8 {
                        return Err(format!("step {step}: overlap {addr} vs ({a},{l})"));
                    }
                }
                live.push((addr, words));
            } else if roll < 0.8 {
                let i = rng.below(live.len());
                let (addr, _) = live.swap_remove(i);
                arena.free(addr);
            } else {
                let i = rng.below(live.len());
                let words = 1 + rng.below(700) as u64;
                let addr = arena.realloc(live[i].0, words);
                live[i] = (addr, words);
            }
            arena.check().map_err(|e| format!("step {step}: {e}"))?;
        }
        // Drain: everything freed coalesces back to one extent.
        for (addr, _) in live.drain(..) {
            arena.free(addr);
        }
        arena.check()?;
        if arena.live_words() != 0 {
            return Err("leak after drain".into());
        }
        Ok(())
    });
}

/// Codec round-trips on adversarial payloads: long runs, alternating
/// patterns, denormals, negative zero, all-dense.
#[test]
fn codec_adversarial_payloads() {
    let patterns: Vec<Vec<f32>> = vec![
        vec![0.0; 1024],
        vec![1.0; 1024],
        (0..1024).map(|i| if i % 2 == 0 { 0.0 } else { 1.5 }).collect(),
        (0..1024).map(|i| if i % 33 == 0 { -2.5 } else { 0.0 }).collect(),
        (0..1024)
            .map(|i| if i < 512 { 0.0 } else { (i as f32 - 700.0) * 1e-3 })
            .collect(),
        vec![-0.0; 64], // negative zero is a zero
        (0..97).map(|i| (i as f32) * 1e30).collect(), // big magnitudes
        (0..97).map(|i| (i as f32) * 1e-30).collect(), // tiny magnitudes
    ];
    for scheme in [Scheme::Bitmask, Scheme::Zrlc, Scheme::Dictionary, Scheme::Raw] {
        let codec = scheme.build();
        for (pi, p) in patterns.iter().enumerate() {
            let quant: Vec<f32> =
                p.iter().map(|&x| gratetile::tensor::dense::bf16_quantise(x)).collect();
            let comp = codec.compress(&quant);
            let mut out = vec![9.0f32; quant.len()];
            codec.decompress(&comp, &mut out);
            // -0.0 compresses as a zero; compare with == (true for ±0).
            assert_eq!(out, quant, "{} pattern {pi}", scheme.name());
            assert_eq!(
                comp.compressed_words(),
                codec.compressed_words(&quant),
                "{} pattern {pi} size fast path",
                scheme.name()
            );
        }
    }
}

/// Bank-arbiter conservation on the serving simulator's shared DRAM:
/// for any geometry, timing and traffic pattern, every transfer cycle
/// is charged to exactly one bank (`sum(bank occupancy) == total
/// transfer cycles`), every line is either a row hit or a row miss,
/// and completion times respect issue order and the command overhead.
#[test]
fn prop_shared_dram_bank_conservation() {
    forall_res(
        0xBA2B,
        60,
        |r: &mut SplitMix64| r.next_u64(),
        |&seed| {
            let mut rng = SplitMix64::new(seed);
            let timing = DramTiming {
                n_banks: [1, 2, 4, 8, 16][rng.below(5)],
                row_bytes: 1024 << rng.below(3),
                t_ccd: 1 + rng.below(8) as u64,
                t_rp_rcd: rng.below(50) as u64,
                t_cmd: rng.below(12) as u64,
            };
            let mut d = SharedDram::new(timing);
            let mut now = 0u64;
            for step in 0..200 {
                let addr = rng.below(1 << 20) as u64;
                let words = rng.below(120) as u64; // includes 0-word requests
                let done = d.service(now, addr, words);
                if words == 0 {
                    if done != now {
                        return Err(format!("step {step}: empty transfer took time"));
                    }
                } else {
                    if done < now + timing.t_cmd + timing.t_ccd {
                        return Err(format!(
                            "step {step}: completion {done} before cmd+transfer"
                        ));
                    }
                    // Sometimes chain (request streams), sometimes issue
                    // concurrently at the same virtual cycle.
                    if rng.chance(0.5) {
                        now = done;
                    } else if rng.chance(0.3) {
                        now += rng.below(64) as u64;
                    }
                }
            }
            let occupancy: u64 = d.bank_busy_cycles().iter().sum();
            if occupancy != d.transfer_cycles {
                return Err(format!(
                    "occupancy {occupancy} != transfer cycles {}",
                    d.transfer_cycles
                ));
            }
            if d.row_hits + d.row_misses != d.lines {
                return Err(format!(
                    "hits {} + misses {} != lines {}",
                    d.row_hits, d.row_misses, d.lines
                ));
            }
            if d.bank_busy_cycles().len() != timing.n_banks {
                return Err("bank occupancy vector has wrong arity".into());
            }
            Ok(())
        },
    );
}

/// Metadata-cache study: the tile *order* (spatial-major vs
/// channel-major) reorders the record stream but touches exactly the
/// same records per window — the requested (no-cache) metadata traffic
/// is order-invariant; only the absorbed fraction may differ.
#[test]
fn prop_metacache_tile_order_traffic_invariant() {
    forall_res(0x7173, 16, gen_scenario, |sc| {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (h, w, c) = (sc.layer.h, sc.layer.w, sc.layer.c_in);
        let fm = generate(h, w, c, SparsityParams::clustered(sc.density, sc.seed));
        let cache_bytes = 512 << (sc.seed % 4);
        let sm = metadata_cache_study(
            &hw, &sc.layer, &fm, sc.mode, cache_bytes, TileOrder::SpatialMajor,
        );
        let cm = metadata_cache_study(
            &hw, &sc.layer, &fm, sc.mode, cache_bytes, TileOrder::ChannelMajor,
        );
        match (sm, cm) {
            (Ok(s), Ok(c)) => {
                if s.requested_bits != c.requested_bits {
                    return Err(format!(
                        "{}: requested bits depend on tile order: {} vs {}",
                        sc.mode.name(),
                        s.requested_bits,
                        c.requested_bits
                    ));
                }
                if s.dram_bits > s.requested_bits || c.dram_bits > c.requested_bits {
                    return Err("cache manufactured traffic".into());
                }
                Ok(())
            }
            (Err(a), Err(b)) if a == b => Ok(()),
            (a, b) => Err(format!("applicability mismatch {a:?} vs {b:?}")),
        }
    });
}

/// Pricer edge geometries, directed at the boundaries the uniform
/// random scenarios rarely hit: strides larger than the processing
/// tile, 1×1(-ish) feature maps, and maps whose last window clips just
/// past a tile boundary. The prefix-sum pricer must stay bit-exact
/// with the naive oracle on all of them (and fail applicability
/// identically).
#[test]
fn prop_pricer_edge_geometries() {
    forall_res(
        0xED6E,
        30,
        |r: &mut SplitMix64| {
            let (k, s, h, w, c) = match r.below(3) {
                // Stride exceeds every tile edge (tiles are <= 16 wide).
                0 => (
                    r.below(3),
                    17 + r.below(8),
                    24 + r.below(40),
                    24 + r.below(40),
                    8,
                ),
                // Degenerate 1x1 .. 3x3 maps.
                1 => (r.below(2), 1 + r.below(2), 1 + r.below(3), 1 + r.below(3), 8 * (1 + r.below(2))),
                // Clipped just past a tile boundary on both axes.
                _ => (
                    1 + r.below(2),
                    1 + r.below(2),
                    8 * (1 + r.below(4)) + 1 + r.below(6),
                    16 * (1 + r.below(2)) + 1 + r.below(6),
                    8,
                ),
            };
            let policy = match r.below(5) {
                0 => CodecPolicy::Fixed(Scheme::Bitmask),
                1 => CodecPolicy::Fixed(Scheme::Zrlc),
                2 => CodecPolicy::Fixed(Scheme::Dictionary),
                3 => CodecPolicy::Fixed(Scheme::Raw),
                _ => CodecPolicy::Adaptive,
            };
            Scenario {
                layer: ConvLayer { k, s, d: 1, h, w, c_in: c, c_out: c },
                mode: DivisionMode::GrateTile { n: 8 }, // swept below
                policy,
                density: r.next_f64(),
                seed: r.next_u64(),
            }
        },
        |sc| {
            let (h, w, c) = (sc.layer.h, sc.layer.w, sc.layer.c_in);
            let fm = generate(h, w, c, SparsityParams::clustered(sc.density, sc.seed));
            for platform in [Platform::NvidiaSmallTile, Platform::EyerissLargeTile] {
                let hw = platform.hardware();
                for mode in DivisionMode::table3_modes() {
                    let fast = run_layer(&hw, &sc.layer, &fm, mode, sc.policy);
                    let slow = run_layer_naive(&hw, &sc.layer, &fm, mode, sc.policy);
                    match (fast, slow) {
                        (Ok(f), Ok(s)) => {
                            if (f.fetched_bits, f.metadata_bits, f.baseline_bits)
                                != (s.fetched_bits, s.metadata_bits, s.baseline_bits)
                            {
                                return Err(format!(
                                    "{} {} k={} s={} {h}x{w}x{c}: pricer ({}, {}, {}) != naive ({}, {}, {})",
                                    hw.name,
                                    mode.name(),
                                    sc.layer.k,
                                    sc.layer.s,
                                    f.fetched_bits,
                                    f.metadata_bits,
                                    f.baseline_bits,
                                    s.fetched_bits,
                                    s.metadata_bits,
                                    s.baseline_bits,
                                ));
                            }
                        }
                        (Err(a), Err(b)) if a == b => {}
                        (f, s) => {
                            return Err(format!(
                                "{} {} k={} s={} {h}x{w}x{c}: applicability mismatch {f:?} vs {s:?}",
                                hw.name,
                                mode.name(),
                                sc.layer.k,
                                sc.layer.s,
                            ))
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// The mod-reduction property at the full-division level: a mod-4
/// GrateTile division's cut set contains the mod-8 division's cuts
/// (N′ | N ⇒ more cuts, never fewer).
#[test]
fn prop_mod_reduction_refines_cuts() {
    forall_res(0x04EF, 80, |r: &mut SplitMix64| {
        let k = r.below(3);
        let s = 1 + r.below(2);
        (k, s, 16 + r.below(48))
    }, |&(k, s, len)| {
        let layer = ConvLayer::new(k, s, 224, 224, 64, 64);
        let hw = Platform::EyerissLargeTile.hardware();
        let tile = hw.tile_for_layer(&layer);
        let d8 = Division::build(DivisionMode::GrateTile { n: 8 }, &layer, &tile, &hw, len, len, 8);
        let d4 = Division::build(DivisionMode::GrateTile { n: 4 }, &layer, &tile, &hw, len, len, 8);
        let (Ok(d8), Ok(d4)) = (d8, d4) else { return Ok(()) };
        let cuts = |d: &Division| -> Vec<usize> {
            d.ys.iter().skip(1).map(|s| s.start).collect()
        };
        let c8 = cuts(&d8);
        let c4 = cuts(&d4);
        for c in &c8 {
            if !c4.contains(c) {
                return Err(format!("mod-4 misses mod-8 cut {c} (k={k},s={s},len={len})"));
            }
        }
        Ok(())
    });
}

/// ISSUE 5 satellite (a): for ANY random map, the adaptive policy's
/// payload+tag bits never exceed the best fixed codec's payload bits
/// plus the same tag budget — per-sub-tensor min selection can only
/// win the payload, and the 2-bit tags are charged identically on both
/// sides of the comparison.
#[test]
fn prop_adaptive_payload_never_exceeds_best_fixed() {
    forall_res(0xADA7, 25, gen_scenario, |sc| {
        let hw = Platform::NvidiaSmallTile.hardware();
        let (h, w, c) = (sc.layer.h, sc.layer.w, sc.layer.c_in);
        let tile = hw.tile_for_layer(&sc.layer);
        let division = match Division::build(sc.mode, &sc.layer, &tile, &hw, h, w, c) {
            Ok(d) => d,
            Err(_) => return Ok(()),
        };
        let fm = generate(h, w, c, SparsityParams::clustered(sc.density, sc.seed));
        let auto = Packer::new(hw, CodecPolicy::Adaptive).pack(&fm, &division, false);
        let auto_fetch: u64 = auto.fetch_bits_grid().iter().sum();
        let tag_bits = auto.meta_total_bits() - division.total_meta_bits();
        for scheme in Registry::global().schemes() {
            let fixed = Packer::new(hw, scheme).pack(&fm, &division, false);
            let fixed_fetch: u64 = fixed.fetch_bits_grid().iter().sum();
            // The genuinely asymmetric bound: adaptive pays its real
            // metadata (base + tags); the fixed side pays base metadata
            // plus the same tag *budget* — per-sub-tensor min selection
            // must cover the comparison even so.
            if auto_fetch + auto.meta_total_bits()
                > fixed_fetch + fixed.meta_total_bits() + tag_bits
            {
                return Err(format!(
                    "{} {}: adaptive {auto_fetch}+{} > fixed {fixed_fetch}+{}+{tag_bits} ({})",
                    sc.mode.name(),
                    sc.density,
                    auto.meta_total_bits(),
                    fixed.meta_total_bits(),
                    scheme.name()
                ));
            }
            if auto.total_words > fixed.total_words {
                return Err(format!(
                    "{}: adaptive footprint {} > fixed {} ({})",
                    sc.mode.name(),
                    auto.total_words,
                    fixed.total_words,
                    scheme.name()
                ));
            }
        }
        Ok(())
    });
}

/// ISSUE 5 satellite (a), strict half: on a mixed-density map (dense
/// top half, near-empty bottom half) the adaptive policy beats EVERY
/// fixed codec strictly, even after paying its tag bits — raw wins the
/// dense sub-tensors, bitmask the sparse ones, and no single codec can
/// have both.
#[test]
fn adaptive_strictly_beats_every_fixed_codec_on_mixed_density_map() {
    use gratetile::tensor::dense::bf16_quantise;
    use gratetile::tensor::FeatureMap;
    let hw = Platform::NvidiaSmallTile.hardware();
    let layer = ConvLayer::new(1, 1, 64, 64, 16, 16);
    let tile = hw.tile_for_layer(&layer);
    let division =
        Division::build(DivisionMode::GrateTile { n: 8 }, &layer, &tile, &hw, 64, 64, 16)
            .unwrap();
    let mut rng = SplitMix64::new(0x3117);
    let data: Vec<f32> = (0..64 * 64 * 16)
        .map(|i| {
            let y = i / (64 * 16);
            if y < 32 {
                // Dense half: every word nonzero, high cardinality.
                bf16_quantise(rng.next_f32() * 9.0 + 0.5)
            } else if rng.chance(0.02) {
                bf16_quantise(rng.next_f32() + 0.25)
            } else {
                0.0
            }
        })
        .collect();
    let fm = FeatureMap::from_vec(64, 64, 16, data);
    let auto = Packer::new(hw, CodecPolicy::Adaptive).pack(&fm, &division, false);
    let auto_total = auto.total_words * 16 + auto.meta_total_bits();
    let mut used: Vec<u8> = auto.tags.clone();
    used.sort_unstable();
    used.dedup();
    assert!(used.len() >= 2, "the mixed map must actually mix codecs: {used:?}");
    for scheme in Registry::global().schemes() {
        let fixed = Packer::new(hw, scheme).pack(&fm, &division, false);
        let fixed_total = fixed.total_words * 16 + fixed.meta_total_bits();
        assert!(
            auto_total < fixed_total,
            "adaptive {auto_total} !< fixed {} {fixed_total}",
            scheme.name()
        );
    }
}

/// ISSUE 6 satellite (a): the GEMM compute backend is **bit-identical**
/// (f32) to the `direct_conv_relu` oracle for every randomized layer
/// geometry — stride/dilation/SAME-padding edges included — under every
/// division mode, codec policy, and all three skip policies. The same
/// runs also assert the fetch-side invariance acceptance: zero-skip
/// decode elision never changes what DRAM traffic is accounted.
#[test]
fn prop_gemm_matches_direct_conv() {
    use gratetile::memsim::Stream;
    forall_res(
        0x6E77,
        12,
        |r: &mut SplitMix64| {
            // Smaller shapes than gen_scenario: every case runs the
            // dense kernel 3x, so keep the MAC budget honest.
            let k = r.below(3); // kernels 1/3/5
            let s = 1 + r.below(2);
            let d = if k > 0 && r.chance(0.25) { 2 } else { 1 };
            let h = 9 + r.below(16);
            let w = 9 + r.below(16);
            let c = 8 * (1 + r.below(2));
            let mode = match r.below(4) {
                0 => DivisionMode::GrateTile { n: 4 },
                1 | 2 => DivisionMode::GrateTile { n: 8 },
                _ => DivisionMode::Uniform { edge: 4 },
            };
            let policy = match r.below(4) {
                0 => CodecPolicy::Fixed(Scheme::Bitmask),
                1 => CodecPolicy::Fixed(Scheme::Zrlc),
                2 => CodecPolicy::Fixed(Scheme::Dictionary),
                _ => CodecPolicy::Adaptive,
            };
            Scenario {
                layer: ConvLayer { k, s, d, h, w, c_in: c, c_out: 8 },
                mode,
                policy,
                density: r.next_f64(),
                seed: r.next_u64(),
            }
        },
        |sc| {
            let hw = Platform::NvidiaSmallTile.hardware();
            let (h, w, c) = (sc.layer.h, sc.layer.w, sc.layer.c_in);
            let fm = generate(h, w, c, SparsityParams::clustered(sc.density, sc.seed));
            let weights = Weights::random(&sc.layer, sc.seed ^ 0x11);
            let oracle = direct_conv_relu(&sc.layer, &weights, &fm);
            let be = GemmBackend::new(hw).with_mode(sc.mode).with_policy(sc.policy);
            let mut runs = Vec::new();
            for skip in SkipPolicy::all() {
                let run = match be.with_skip(skip).conv_relu(&sc.layer, &weights, &fm) {
                    Ok(r) => r,
                    Err(_) => return Ok(()), // N/A division for this geometry
                };
                let tag = format!(
                    "k={} s={} d={} {h}x{w}x{c} {} {} {}",
                    sc.layer.k,
                    sc.layer.s,
                    sc.layer.d,
                    sc.mode.name(),
                    sc.policy.name(),
                    skip.name()
                );
                if run.out.as_slice() != oracle.as_slice() {
                    return Err(format!("{tag}: GEMM diverges from oracle"));
                }
                if run.stats.dense_macs == 0 {
                    return Err(format!("{tag}: kernel measured nothing"));
                }
                runs.push(run);
            }
            // Dense vs ZeroSkip: the compute policy must not change one
            // word of accounted DRAM traffic.
            for s in [Stream::FeatureRead, Stream::MetadataRead] {
                if runs[0].dram.words_of(s) != runs[2].dram.words_of(s) {
                    return Err(format!(
                        "{} {}: {s:?} traffic {} (dense) != {} (zeroskip)",
                        sc.mode.name(),
                        sc.policy.name(),
                        runs[0].dram.words_of(s),
                        runs[2].dram.words_of(s)
                    ));
                }
            }
            if runs[0].stats.dense_macs != runs[2].stats.dense_macs {
                return Err("dense-equivalent MACs must be policy-invariant".into());
            }
            Ok(())
        },
    );
}

/// ISSUE 6 acceptance: GEMM == oracle bit for bit across the layer
/// *zoo* geometries under every codec policy including Adaptive. The
/// suite's kernel/stride/dilation diversity is kept; spatial and
/// channel extents are capped so the dense oracle stays affordable in
/// a debug test run.
#[test]
fn gemm_matches_oracle_on_layer_zoo_all_policies() {
    use gratetile::config::zoo::benchmark_suite;
    let hw = Platform::NvidiaSmallTile.hardware();
    let mut checked = 0;
    for (i, bench) in benchmark_suite().iter().enumerate() {
        let b = bench.layer;
        let layer = ConvLayer {
            k: b.k,
            s: b.s,
            d: b.d,
            h: b.h.min(18),
            w: b.w.min(18),
            c_in: b.c_in.min(16),
            c_out: b.c_out.min(8),
        };
        let fm = generate(
            layer.h,
            layer.w,
            layer.c_in,
            SparsityParams::clustered(bench.density, 0x200 + i as u64),
        );
        let weights = Weights::random(&layer, 0x300 + i as u64);
        let oracle = direct_conv_relu(&layer, &weights, &fm);
        for policy in [
            CodecPolicy::Fixed(Scheme::Bitmask),
            CodecPolicy::Fixed(Scheme::Zrlc),
            CodecPolicy::Adaptive,
        ] {
            let Ok(run) = GemmBackend::new(hw)
                .with_policy(policy)
                .conv_relu(&layer, &weights, &fm)
            else {
                continue;
            };
            assert_eq!(
                run.out.as_slice(),
                oracle.as_slice(),
                "{} {} {policy:?}",
                bench.network.name(),
                bench.name
            );
            checked += 1;
        }
    }
    assert!(checked >= 30, "zoo coverage too small: {checked}");
}

/// ISSUE 5 acceptance: on the standard layer zoo (the Table III
/// benchmark suite), adaptive total (payload + metadata + tag) bits
/// never exceed the best fixed codec's total with the same tag budget
/// charged to both sides.
#[test]
fn adaptive_never_exceeds_best_fixed_on_layer_zoo() {
    use gratetile::config::zoo::benchmark_suite;
    use gratetile::sim::experiment::bench_feature_map;
    let hw = Platform::EyerissLargeTile.hardware();
    let mode = DivisionMode::GrateTile { n: 8 };
    let mut checked = 0;
    for bench in benchmark_suite() {
        let fm = bench_feature_map(&bench);
        let tile = hw.tile_for_layer(&bench.layer);
        let Ok(division) =
            Division::build(mode, &bench.layer, &tile, &hw, fm.h, fm.w, fm.c)
        else {
            continue;
        };
        let auto = Packer::new(hw, CodecPolicy::Adaptive).pack(&fm, &division, false);
        let tag_bits = auto.meta_total_bits() - division.total_meta_bits();
        let auto_total = auto.total_words * 16 + auto.meta_total_bits();
        let best_fixed = Registry::global()
            .schemes()
            .into_iter()
            .map(|s| {
                let p = Packer::new(hw, s).pack(&fm, &division, false);
                p.total_words * 16 + p.meta_total_bits() + tag_bits
            })
            .min()
            .unwrap();
        assert!(
            auto_total <= best_fixed,
            "{} {}: adaptive {auto_total} > best fixed {best_fixed}",
            bench.network.name(),
            bench.name
        );
        checked += 1;
    }
    assert!(checked >= 15, "zoo coverage too small: {checked}");
}
