//! Smoke validation of the Table III reproduction shape: run the full
//! benchmark suite on both platforms and assert the paper's orderings
//! (who wins, roughly by how much). Run with `--ignored` for the full
//! suite; the default test uses a reduced suite for CI speed.

use gratetile::compress::Scheme;
use gratetile::config::{benchmark_suite, Platform};
use gratetile::sim::experiment::run_suite;
use gratetile::tiling::DivisionMode;

fn print_suite(platform: Platform) -> Vec<(String, Option<f64>, Option<f64>)> {
    let hw = platform.hardware();
    let benches = benchmark_suite();
    let modes = DivisionMode::table3_modes();
    let suite = run_suite(&hw, &benches, &modes, Scheme::Bitmask);
    let mut rows = Vec::new();
    println!("== {} (optimal {:.1}%) ==", hw.name, suite.geomean_optimal() * 100.0);
    for (i, m) in modes.iter().enumerate() {
        let wo = suite.geomean_saving(i, false);
        let wi = suite.geomean_saving(i, true);
        println!(
            "{:<22} without {:>6}  with {:>6}",
            m.name(),
            wo.map(|v| format!("{:.1}%", v * 100.0)).unwrap_or("N/A".into()),
            wi.map(|v| format!("{:.1}%", v * 100.0)).unwrap_or("N/A".into()),
        );
        rows.push((m.name(), wo, wi));
    }
    rows
}

#[test]
#[ignore = "full-suite smoke; run explicitly"]
fn table3_shape_holds() {
    for platform in [Platform::NvidiaSmallTile, Platform::EyerissLargeTile] {
        let rows = print_suite(platform);
        let get = |name: &str, with: bool| -> Option<f64> {
            rows.iter().find(|r| r.0 == name).and_then(|r| if with { r.2 } else { r.1 })
        };
        let g8 = get("GrateTile (mod 8)", true).unwrap();
        let u8_ = get("Uniform 8x8x8", true).unwrap();
        let u4 = get("Uniform 4x4x8", true).unwrap();
        let u2 = get("Uniform 2x2x8", true).unwrap();
        let u1 = get("Uniform 1x1x8", true).unwrap();
        let u1_wo = get("Uniform 1x1x8", false).unwrap();
        let g8_wo = get("GrateTile (mod 8)", false).unwrap();
        // Paper: GrateTile mod 8 beats every uniform division.
        assert!(g8 > u8_ && g8 > u4 && g8 > u2 && g8 > u1, "mod8 must win");
        // Paper: ~55% overall saving for mod 8.
        assert!((0.45..0.65).contains(&g8), "mod8 saving {g8}");
        // Paper: 1x1x8 without overhead is the upper bound; its 25%
        // metadata then collapses it by >20pp to the bottom of the table.
        assert!(u1_wo >= g8_wo - 0.02, "compact upper bound");
        assert!(u1_wo - u1 > 0.20, "compact must collapse under metadata");
        assert!(u1 < g8 && u1 < u4, "compact-with-meta loses to mod8 and u4");
    }
}
