//! Observability-layer integration suite (ISSUE 7 acceptance).
//!
//! Pins the contracts that make the trace/metrics artifacts shippable:
//! spans are well-nested per track under arbitrary simulator knobs, the
//! per-bank DRAM occupancy tracks reconcile **exactly** with the
//! report's `bank_busy_cycles`, the Chrome trace JSON and the metrics
//! dump are byte-identical across `--jobs` {1, 2, 8}, the log-bucketed
//! histogram honours its documented error bound against exact sorted
//! quantiles, and the canonical serve trace is a golden fixture.

use gratetile::config::hardware::Platform;
use gratetile::config::layer::ConvLayer;
use gratetile::coordinator::simserver::{
    metrics_of, simulate, simulate_traced, RequestTrace, SimServer, SimServerConfig,
};
use gratetile::coordinator::{PipelineConfig, Weights};
use gratetile::memsim::DramTiming;
use gratetile::obs::metrics::{percentile_index, LogHistogram};
use gratetile::obs::trace::{ADMISSION_PID, DRAM_PID, TraceRecorder, WORKER_PID};
use gratetile::util::parallel::set_threads;
use gratetile::util::proptest_lite::{forall_res, SparseVecGen};
use gratetile::util::rng::SplitMix64;
use std::path::{Path, PathBuf};

fn golden_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// Same bless-on-missing golden helper as `tests/golden.rs` (test
/// binaries cannot share non-crate code without a support crate).
fn check_golden(name: &str, actual: &str) {
    let path = golden_dir().join(name);
    let bless = std::env::var("GRATETILE_BLESS").map(|v| v == "1").unwrap_or(false);
    if bless || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, actual).unwrap();
        eprintln!("golden: blessed {} ({} bytes)", path.display(), actual.len());
        return;
    }
    let expected = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    if expected == actual {
        return;
    }
    let exp: Vec<&str> = expected.lines().collect();
    let act: Vec<&str> = actual.lines().collect();
    let mut msg = format!("golden mismatch against {}\n", path.display());
    for i in 0..exp.len().max(act.len()) {
        if exp.get(i) != act.get(i) {
            msg.push_str(&format!(
                "  first difference at line {}:\n    expected: {}\n    actual:   {}\n",
                i + 1,
                exp.get(i).copied().unwrap_or("<missing>"),
                act.get(i).copied().unwrap_or("<missing>")
            ));
            break;
        }
    }
    msg.push_str(
        "if the new output is intended, re-bless with \
         `GRATETILE_BLESS=1 cargo test --test obs` and commit the diff",
    );
    panic!("{msg}");
}

fn tiny_net() -> Vec<(ConvLayer, Weights)> {
    let l1 = ConvLayer::new(1, 1, 16, 16, 8, 8);
    let l2 = ConvLayer::new(1, 2, 16, 16, 8, 8);
    vec![(l1, Weights::random(&l1, 1)), (l2, Weights::random(&l2, 2))]
}

fn base_cfg() -> SimServerConfig {
    SimServerConfig::new(PipelineConfig::new(
        Platform::NvidiaSmallTile.hardware(),
    ))
}

/// One functional pass shared by the timing-pass tests: re-simulating
/// the same traces under many knob settings needs no new pass.
fn canonical_traces() -> Vec<RequestTrace> {
    let server = SimServer::new(base_cfg(), tiny_net());
    let reqs = server.synthetic_requests(6, 0.5, 7);
    server.functional_pass(&reqs).expect("functional pass")
}

/// Simulator knobs the well-nestedness property sweeps.
#[derive(Debug, Clone)]
struct Knobs {
    workers: usize,
    queue_depth: usize,
    batch: usize,
    pe_lanes: u64,
    banks: usize,
    arrival_gap: u64,
}

fn apply(knobs: &Knobs, mut cfg: SimServerConfig) -> SimServerConfig {
    cfg.workers = knobs.workers;
    cfg.queue_depth = knobs.queue_depth;
    cfg.batch = knobs.batch;
    cfg.pe_lanes = knobs.pe_lanes;
    cfg.timing = DramTiming { n_banks: knobs.banks, ..DramTiming::default() };
    cfg.arrival_gap = knobs.arrival_gap;
    cfg
}

/// Property (ISSUE 7 satellite c-i): for arbitrary worker/queue/batch/
/// PE/bank/arrival configurations, every recorded span set is
/// well-nested per track — children never cross their parents.
#[test]
fn traced_spans_are_well_nested_for_arbitrary_configs() {
    let traces = canonical_traces();
    let gen = |r: &mut SplitMix64| Knobs {
        workers: r.range(1, 4),
        queue_depth: r.range(1, 8),
        batch: r.range(1, 3),
        pe_lanes: [1u64, 8, 32, 256][r.below(4)],
        banks: r.range(1, 8),
        arrival_gap: [0u64, 40, 700][r.below(3)],
    };
    forall_res(0x0B5E_2026, 24, gen, |knobs| {
        let cfg = apply(knobs, base_cfg());
        let mut rec = TraceRecorder::enabled();
        let report = simulate_traced(&cfg, &traces, &mut rec);
        if report.completed != traces.len() as u64 {
            return Err(format!("only {} of {} completed", report.completed, traces.len()));
        }
        if rec.spans().is_empty() {
            return Err("no spans recorded".into());
        }
        rec.check_well_nested()
    });
}

/// ISSUE 7 acceptance: the per-bank `busy` span totals on the DRAM
/// tracks reconcile **exactly** with `SimServerReport.bank_busy_cycles`
/// — not approximately, bank by bank.
#[test]
fn bank_tracks_reconcile_exactly_with_report() {
    let traces = canonical_traces();
    let mut cfg = base_cfg();
    cfg.workers = 1; // serialise grants so admission waits also appear
    let mut rec = TraceRecorder::enabled();
    let report = simulate_traced(&cfg, &traces, &mut rec);

    let mut per_bank = vec![0u64; report.n_banks];
    for sp in rec.spans().iter().filter(|sp| sp.track.pid == DRAM_PID) {
        assert_eq!(sp.name, "busy");
        per_bank[sp.track.tid as usize] += sp.end - sp.start;
    }
    assert_eq!(per_bank, report.bank_busy_cycles);
    assert!(per_bank.iter().sum::<u64>() > 0, "no DRAM occupancy recorded");

    // The other track families also materialised: request spans on the
    // worker track, non-zero `wait` spans on the admission tracks.
    let has_req = rec
        .spans()
        .iter()
        .any(|sp| sp.track.pid == WORKER_PID && sp.name.starts_with("req "));
    let has_wait = rec
        .spans()
        .iter()
        .any(|sp| sp.track.pid == ADMISSION_PID && sp.name == "wait" && sp.end > sp.start);
    assert!(has_req, "no request spans on the worker track");
    assert!(has_wait, "one worker must force non-empty admission waits");
}

/// ISSUE 7 acceptance + satellite c-iii: the Chrome trace JSON and the
/// metrics dump are byte-identical across `--jobs` {1, 2, 8} — the
/// functional pass may parallelise, emission may not.
#[test]
fn trace_and_metrics_bytes_invariant_across_jobs() {
    let server = SimServer::new(base_cfg(), tiny_net());
    let reqs = server.synthetic_requests(6, 0.5, 7);
    let mut outputs: Vec<(usize, String, String)> = Vec::new();
    for jobs in [1usize, 2, 8] {
        set_threads(jobs);
        let traces = server.functional_pass(&reqs).unwrap();
        let mut rec = TraceRecorder::enabled();
        let report = simulate_traced(server.cfg(), &traces, &mut rec);
        outputs.push((jobs, rec.to_chrome_json(), metrics_of(&report, &traces).to_json()));
    }
    set_threads(0);
    for (jobs, trace, metrics) in &outputs[1..] {
        assert_eq!(trace, &outputs[0].1, "trace bytes diverge at --jobs {jobs}");
        assert_eq!(metrics, &outputs[0].2, "metrics bytes diverge at --jobs {jobs}");
    }
}

/// Property (ISSUE 7 satellite c-ii): for arbitrary sample sets, every
/// histogram quantile is within the documented log-bucket error bound
/// of the exact sorted quantile: `q̂ ≤ exact ≤ q̂ + (q̂ >> 3)`.
#[test]
fn histogram_quantiles_honour_documented_bound() {
    let gen = |r: &mut SplitMix64| -> Vec<u64> {
        let n = r.range(1, 200);
        (0..n).map(|_| r.next_u64() >> r.range(8, 63)).collect()
    };
    forall_res(0x41_57_06_2026, 128, gen, |samples| {
        let mut h = LogHistogram::new();
        for &v in samples {
            h.observe(v);
        }
        let mut sorted = samples.clone();
        sorted.sort_unstable();
        for p in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            let exact = sorted[percentile_index(sorted.len(), p)];
            let qh = h.quantile(p);
            if !(qh <= exact && exact <= qh + (qh >> 3)) {
                return Err(format!(
                    "p={p}: quantile {qh} vs exact {exact} breaks the bucket bound"
                ));
            }
        }
        Ok(())
    });
}

/// Histograms built through `SparseVecGen`-shaped float data still obey
/// the bound after quantisation to integer cycles — the serving
/// report's actual usage shape.
#[test]
fn histogram_bound_holds_for_latency_shaped_data() {
    let gen = SparseVecGen { max_len: 160, zero_p: 0.3 };
    forall_res(0x1A7E_2026, 64, gen, |values| {
        if values.is_empty() {
            return Ok(());
        }
        let samples: Vec<u64> = values.iter().map(|v| (v * 1e4) as u64).collect();
        let mut h = LogHistogram::new();
        for &v in &samples {
            h.observe(v);
        }
        let mut sorted = samples.clone();
        sorted.sort_unstable();
        for p in [0.5, 0.95, 0.99] {
            let exact = sorted[percentile_index(sorted.len(), p)];
            let qh = h.quantile(p);
            if !(qh <= exact && exact <= qh + (qh >> 3)) {
                return Err(format!("p={p}: {qh} vs {exact}"));
            }
        }
        Ok(())
    });
}

/// Extract `"key":<digits>` from a Chrome trace-event line.
fn field_u64(line: &str, key: &str) -> Option<u64> {
    let tag = format!("\"{key}\":");
    let at = line.find(&tag)? + tag.len();
    let digits: String = line[at..].chars().take_while(char::is_ascii_digit).collect();
    digits.parse().ok()
}

/// The canonical serve trace: Chrome trace-event shape (required keys,
/// monotonic `ts` per track, non-negative `dur`, both worker and DRAM
/// span pids present) and the golden fixture, byte for byte.
#[test]
fn serve_trace_chrome_shape_and_golden() {
    let traces = canonical_traces();
    let mut rec = TraceRecorder::enabled();
    let report = simulate_traced(&base_cfg(), &traces, &mut rec);
    assert_eq!(report.completed, 6);
    let json = rec.to_chrome_json();

    assert!(json.starts_with("{\"traceEvents\":[\n"));
    assert!(json.contains("\"clock\":\"simulated-cycles\""));
    let mut span_pids = std::collections::BTreeSet::new();
    let mut last_ts: std::collections::BTreeMap<(u64, u64), u64> = Default::default();
    let mut events = 0;
    for line in json.lines().filter(|l| l.contains("\"ph\":")) {
        let pid = field_u64(line, "pid").expect("pid");
        let tid = field_u64(line, "tid").expect("tid");
        assert!(line.contains("\"name\":\""), "unnamed event: {line}");
        events += 1;
        if line.contains("\"ph\":\"M\"") {
            continue; // metadata carries no ts
        }
        let ts = field_u64(line, "ts").expect("ts");
        if line.contains("\"ph\":\"X\"") {
            span_pids.insert(pid);
            let dur = field_u64(line, "dur").expect("dur");
            assert!(ts + dur >= ts, "dur overflows: {line}");
        }
        if let Some(prev) = last_ts.insert((pid, tid), ts) {
            assert!(prev <= ts, "ts regressed on ({pid},{tid}): {line}");
        }
    }
    assert!(events > 0);
    assert!(
        span_pids.contains(&WORKER_PID) && span_pids.contains(&DRAM_PID),
        "expected span events on both worker and DRAM tracks, got pids {span_pids:?}"
    );

    check_golden("serve_trace.json", &json);
}

/// A disabled recorder is inert: it collects nothing, and threading it
/// through the timing pass leaves the report byte-identical to the
/// untraced `simulate` path (the goldens' no-regression guarantee).
#[test]
fn disabled_recorder_leaves_report_untouched() {
    let traces = canonical_traces();
    let cfg = base_cfg();
    let plain = simulate(&cfg, &traces);
    let mut rec = TraceRecorder::disabled();
    let threaded = simulate_traced(&cfg, &traces, &mut rec);
    assert_eq!(plain.render(), threaded.render());
    assert!(rec.spans().is_empty() && rec.counters().is_empty());
}
