//! Golden-report regression suite + determinism tests.
//!
//! The serving simulator and the harness tables are deterministic pure
//! functions of their seeds, so their rendered bytes are assertable
//! artifacts: any unintended change to cycle accounting, traffic
//! pricing or formatting shows up as a byte diff against the fixtures
//! in `tests/golden/` (see its README; re-bless with
//! `GRATETILE_BLESS=1`). The determinism tests additionally pin the
//! *contract* that makes golden-filing sound: the simulated
//! `ServerReport` is byte-identical across host worker counts
//! (`--jobs` 1/2/8) and across runs with the same seed.

use gratetile::config::hardware::Platform;
use gratetile::config::layer::ConvLayer;
use gratetile::coordinator::simserver::{SimServer, SimServerConfig};
use gratetile::coordinator::{PipelineConfig, Weights};
use gratetile::harness;
use gratetile::util::parallel::set_threads;
use std::path::{Path, PathBuf};

fn golden_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// Compare `actual` against the checked-in fixture `name`, blessing it
/// when `GRATETILE_BLESS=1` or when the fixture does not exist yet.
/// Mismatches panic with the first differing lines and re-bless
/// instructions.
fn check_golden(name: &str, actual: &str) {
    let path = golden_dir().join(name);
    let bless = std::env::var("GRATETILE_BLESS").map(|v| v == "1").unwrap_or(false);
    if bless || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, actual).unwrap();
        eprintln!("golden: blessed {} ({} bytes)", path.display(), actual.len());
        return;
    }
    let expected = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    if expected == actual {
        return;
    }
    let mut msg = format!("golden mismatch against {}\n", path.display());
    let exp: Vec<&str> = expected.lines().collect();
    let act: Vec<&str> = actual.lines().collect();
    let mut shown = 0;
    for i in 0..exp.len().max(act.len()) {
        let e = exp.get(i).copied();
        let a = act.get(i).copied();
        if e != a {
            msg.push_str(&format!(
                "  line {}:\n    expected: {}\n    actual:   {}\n",
                i + 1,
                e.unwrap_or("<missing>"),
                a.unwrap_or("<missing>")
            ));
            shown += 1;
            if shown == 3 {
                msg.push_str("  ... (further differences elided)\n");
                break;
            }
        }
    }
    msg.push_str(
        "if the new output is intended, re-bless with \
         `GRATETILE_BLESS=1 cargo test --test golden` and commit the diff",
    );
    panic!("{msg}");
}

fn tiny_net() -> Vec<(ConvLayer, Weights)> {
    let l1 = ConvLayer::new(1, 1, 16, 16, 8, 8);
    let l2 = ConvLayer::new(1, 2, 16, 16, 8, 8);
    vec![(l1, Weights::random(&l1, 1)), (l2, Weights::random(&l2, 2))]
}

fn sim_server() -> SimServer {
    let cfg =
        SimServerConfig::new(PipelineConfig::new(Platform::NvidiaSmallTile.hardware()));
    SimServer::new(cfg, tiny_net())
}

/// The headline golden: the simulated serving report, bytes and all.
#[test]
fn golden_sim_serve_report() {
    let server = sim_server();
    let report = server.serve(server.synthetic_requests(6, 0.5, 7)).unwrap();
    check_golden("serve_report.txt", &report.render());
}

/// The same serving report under `--codec auto`: per-sub-tensor codec
/// selection flows through the whole store-resident pipeline and the
/// simulated cycle accounting, deterministically.
#[test]
fn golden_sim_serve_report_auto_codec() {
    use gratetile::compress::CodecPolicy;
    let mut cfg =
        SimServerConfig::new(PipelineConfig::new(Platform::NvidiaSmallTile.hardware()));
    cfg.pipeline.policy = CodecPolicy::Adaptive;
    let server = SimServer::new(cfg, tiny_net());
    let report = server.serve(server.synthetic_requests(6, 0.5, 7)).unwrap();
    check_golden("serve_report_auto.txt", &report.render());
}

/// ISSUE acceptance: the simulated report is byte-identical across
/// host worker counts — `--jobs` ∈ {1, 2, 8} — cycles, per-request
/// latencies and feature bytes included.
#[test]
fn sim_serve_report_identical_across_jobs() {
    let server = sim_server();
    let reqs = server.synthetic_requests(8, 0.45, 21);
    let mut renders = Vec::new();
    for jobs in [1usize, 2, 8] {
        set_threads(jobs);
        let report = server.serve(reqs.clone()).unwrap();
        renders.push((jobs, report.render()));
    }
    set_threads(0);
    for (jobs, r) in &renders[1..] {
        assert_eq!(
            r, &renders[0].1,
            "report bytes diverge between --jobs 1 and --jobs {jobs}"
        );
    }
}

/// Same seed ⇒ same bytes across independent runs; different seed ⇒
/// different simulated outcome (the report really depends on the data).
#[test]
fn sim_serve_report_seed_determinism() {
    let server = sim_server();
    let a = server.serve(server.synthetic_requests(5, 0.5, 33)).unwrap();
    let b = server.serve(server.synthetic_requests(5, 0.5, 33)).unwrap();
    assert_eq!(a.render(), b.render());
    let c = server.serve(server.synthetic_requests(5, 0.5, 34)).unwrap();
    assert_ne!(
        a.render(),
        c.render(),
        "a different request seed must change the report"
    );
}

/// Harness tables: the §V codec ablation ...
#[test]
fn golden_ablation_codecs() {
    check_golden("ablation_codecs.csv", &harness::ablation_codecs().render_csv());
}

/// ... the DRAM access-efficiency study (timed LPDDR4-class model) ...
#[test]
fn golden_access_table() {
    check_golden("access.csv", &harness::access_table().render_csv());
}

/// ... the metadata SRAM-cache absorption study ...
#[test]
fn golden_metacache_table() {
    check_golden("metacache.csv", &harness::metacache_table().render_csv());
}

/// ... and the serve-scaling study driven by the simulator itself.
#[test]
fn golden_serve_scaling_table() {
    check_golden("serve_scaling.csv", &harness::serve_scaling_table().render_csv());
}

/// ISSUE 7 acceptance: the trace counter rollup — final cumulative
/// values of every counter series the traced serving simulation emits —
/// is a golden artifact, byte-stable across `--jobs`.
#[test]
fn golden_trace_rollup_table() {
    let mut renders = Vec::new();
    for jobs in [1usize, 4] {
        set_threads(jobs);
        renders.push(harness::trace_rollup_table().render_csv());
    }
    set_threads(0);
    assert_eq!(renders[0], renders[1], "trace rollup bytes depend on --jobs");
    check_golden("trace_rollup.csv", &renders[0]);
}

/// ISSUE 8 tentpole acceptance: the chaos study — seeded fault
/// injection swept against defense policies — is a golden artifact,
/// byte-identical across `--jobs` ∈ {1, 2, 8}. Fault decisions are
/// pure hashes of (seed, site, request, address), so neither the
/// functional fan-out width nor host scheduling may leak into a byte.
#[test]
fn golden_chaos_table_identical_across_jobs() {
    let mut renders = Vec::new();
    for jobs in [1usize, 2, 8] {
        set_threads(jobs);
        renders.push((jobs, harness::chaos_table().render_csv()));
    }
    set_threads(0);
    for (jobs, r) in &renders[1..] {
        assert_eq!(
            r, &renders[0].1,
            "chaos table bytes diverge between --jobs 1 and --jobs {jobs}"
        );
    }
    check_golden("chaos.csv", &renders[0].1);
}

/// ISSUE 6 satellite (d): the GEMM compute-backend study table —
/// measured MAC counts, skip counters and oracle bit-exactness flags —
/// is a golden artifact, byte-stable across `--jobs`.
#[test]
fn golden_gemm_table() {
    let mut renders = Vec::new();
    for jobs in [1usize, 4] {
        set_threads(jobs);
        renders.push(harness::gemm_table().render_csv());
    }
    set_threads(0);
    assert_eq!(renders[0], renders[1], "gemm table bytes depend on --jobs");
    check_golden("gemm_table.csv", &renders[0]);
}

/// ISSUE 9 satellite: the auto-tuning study — per-layer tuned plans vs
/// the fixed presets over the default zoo networks — and the tuned
/// manifest it emits are golden artifacts, byte-stable across `--jobs`
/// (the search runs serially per layer; only the packer's
/// position-indexed sizing pass fans out).
#[test]
fn golden_tune_study_identical_across_jobs() {
    let mut renders = Vec::new();
    for jobs in [1usize, 4] {
        set_threads(jobs);
        let (t, m) = harness::tune_study(harness::TUNE_STUDY_NETWORKS);
        renders.push((t.render_csv(), m.render()));
    }
    set_threads(0);
    assert_eq!(renders[0], renders[1], "tune study bytes depend on --jobs");
    check_golden("tune_study.csv", &renders[0].0);
    check_golden("tuned_manifest.txt", &renders[0].1);
}

/// ISSUE 9 satellite: tuned-manifest round trip across the whole
/// pipeline. Tune the tiny serving net, pack a map under the tuned plan
/// (`store pack --tuned` in library form), export → container →
/// verify → fetch back bit-exactly, then serve the net under the
/// parsed plans and golden the simulated report.
#[test]
fn golden_tuned_roundtrip_pack_inspect_serve() {
    use gratetile::memsim::Dram;
    use gratetile::store::{Container, TensorStore};
    use gratetile::tensor::sparsity::{generate, SparsityParams};
    use gratetile::tensor::FeatureMap;
    use gratetile::tune::{TunedManifest, Tuner};
    let hw = Platform::NvidiaSmallTile.hardware();
    let net = tiny_net();
    // One representative input map per layer position, at the serving
    // tests' density class.
    let named: Vec<(String, ConvLayer, FeatureMap)> = net
        .iter()
        .enumerate()
        .map(|(i, (l, _))| {
            let fm = generate(l.h, l.w, l.c_in, SparsityParams::clustered(0.5, 7 + i as u64));
            (format!("l{i}"), *l, fm)
        })
        .collect();
    let (manifest, _) = Tuner::new(hw).tune_network(&named);
    // The manifest text round-trips losslessly.
    let parsed = TunedManifest::parse(&manifest.render()).unwrap();
    assert_eq!(parsed, manifest);

    // Pack the first layer's map under its tuned plan, push it through
    // the store container boundary, and read it back bit-exactly.
    let runner = gratetile::coordinator::LayerRunner::new(PipelineConfig::new(hw))
        .with_plans(parsed.plans());
    let plan = runner.plan_for(0);
    let packed = runner.pack_with(&named[0].1, &named[0].2, plan.mode, plan.policy).unwrap();
    let mut store = TensorStore::new();
    store.insert_packed("act0", &packed).unwrap();
    let path = std::env::temp_dir().join("gratetile-golden-tuned.grate");
    Container::write(&path, &[("act0".to_string(), &store.export("act0").unwrap())]).unwrap();
    let c = Container::open(&path).unwrap();
    c.verify().unwrap();
    let mut dram = Dram::default();
    let dense = c.fetch_dense("act0", &mut dram).unwrap();
    assert_eq!(dense.as_slice(), named[0].2.as_slice(), "tuned pack round trip");
    std::fs::remove_file(&path).ok();

    // Serve the net under the parsed tuned plans: the report is a
    // golden artifact like its untuned siblings.
    let server = sim_server().with_plans(parsed.plans());
    let report = server.serve(server.synthetic_requests(6, 0.5, 7)).unwrap();
    check_golden("serve_report_tuned.txt", &report.render());
}
