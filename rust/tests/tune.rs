//! Auto-tuner acceptance harness (DESIGN.md §Auto-tuner).
//!
//! The claims this file pins, in order:
//!
//! 1. **Never worse** — the tuned plan's priced total (fetch + metadata
//!    bits) is ≤ every fixed preset (all Table III divisions + WholeMap
//!    × all registry codecs × both tile orders; [`WalkCost`] is
//!    order-invariant so both orders price identically), re-priced
//!    through the *independent* pack-then-price path rather than the
//!    tuner's own sizing grids.
//! 2. **Exactness** — branch-and-bound with the admissible lower bound
//!    equals brute-force enumeration of the full candidate space on
//!    small plan spaces: pruning never discards the optimum.
//! 3. **Determinism** — the tuned manifest and study table are
//!    byte-identical across `--jobs` ∈ {1, 2, 8} and across repeated
//!    runs; the memo-hit path is bit-identical to the cold path.
//! 4. **Pricer seams** — the extended split-point divisions the tuner
//!    searches (anchored rims at 1 and edge−1, degenerate
//!    single-sub-tensor cuts) price bit-exactly against the naive
//!    walker oracle, and the record/tag-bit accounting under adaptive
//!    plans matches the `record_slots` closed form.

use gratetile::compress::{CodecPolicy, Scheme, TAG_BITS};
use gratetile::config::hardware::Platform;
use gratetile::config::layer::ConvLayer;
use gratetile::config::zoo::Network;
use gratetile::harness::tune_study;
use gratetile::layout::metadata::record_bits_for;
use gratetile::sim::experiment::{run_layer, run_layer_naive};
use gratetile::tensor::sparsity::{generate, SparsityParams};
use gratetile::tensor::FeatureMap;
use gratetile::tiling::division::{Division, DivisionMode};
use gratetile::tune::{candidate_modes, candidate_policies, TunedManifest, Tuner};
use gratetile::util::parallel::set_threads;
use gratetile::util::proptest_lite::forall_res;
use gratetile::util::SplitMix64;

/// Random layer-zoo point: geometry × density × sparsity seed.
#[derive(Debug, Clone)]
struct Zoo {
    layer: ConvLayer,
    density: f64,
    seed: u64,
}

fn gen_zoo(r: &mut SplitMix64) -> Zoo {
    let k = r.below(3); // kernels 1/3/5
    let s = 1 + r.below(2);
    let d = if k > 0 && r.chance(0.2) { 2 } else { 1 };
    let h = 9 + r.below(28);
    let w = 9 + r.below(28);
    let c = 8 * (1 + r.below(3));
    Zoo {
        layer: ConvLayer { k, s, d, h, w, c_in: c, c_out: c },
        density: 0.05 + 0.85 * r.next_f64(),
        seed: r.next_u64(),
    }
}

fn fm_of(z: &Zoo) -> FeatureMap {
    generate(z.layer.h, z.layer.w, z.layer.c_in, SparsityParams::clustered(z.density, z.seed))
}

/// Priced total of one (mode, policy) through the independent
/// pack-then-price path ([`run_layer`]): packer sizing, real codec
/// selection, `LayerPricer::new(&packed)`. `None` when the division
/// does not exist for the layer (Table III footnote a).
fn packed_total(
    hw: &gratetile::config::hardware::Hardware,
    layer: &ConvLayer,
    fm: &FeatureMap,
    mode: DivisionMode,
    policy: CodecPolicy,
) -> Option<u64> {
    run_layer(hw, layer, fm, mode, policy).ok().map(|b| b.fetched_bits + b.metadata_bits)
}

/// Satellite 1: the tuned plan is never worse than any fixed preset,
/// and its priced cost is reproduced bit-exactly by the real packer —
/// the search's sizing-grid arithmetic is not a private cost model.
#[test]
fn prop_tuned_never_worse() {
    forall_res(0x71ED, 10, gen_zoo, |z| {
        let hw = Platform::NvidiaSmallTile.hardware();
        let fm = fm_of(z);
        let mut tuner = Tuner::new(hw);
        let r = tuner.tune_layer(&z.layer, &fm);
        let tuned = r.total_bits();
        // The winning plan re-priced through pack-then-price.
        match packed_total(&hw, &z.layer, &fm, r.plan.mode, r.plan.policy) {
            Some(t) if t == tuned => {}
            other => {
                return Err(format!(
                    "tuned plan {} re-prices to {other:?}, search said {tuned}",
                    r.plan.key()
                ))
            }
        }
        // ≤ every preset × codec. WalkCost is tile-order invariant, so
        // this covers both orders of every preset plan.
        for (mode, preset) in candidate_modes(&z.layer) {
            if !preset {
                continue;
            }
            for policy in candidate_policies() {
                let Some(t) = packed_total(&hw, &z.layer, &fm, mode, policy) else { continue };
                if tuned > t {
                    return Err(format!(
                        "tuned {tuned} ({}) worse than preset {} {} = {t} on {:?}",
                        r.plan.key(),
                        mode.name(),
                        policy.name(),
                        z.layer
                    ));
                }
            }
        }
        // The reported best-preset column is itself achievable.
        match packed_total(&hw, &z.layer, &fm, r.best_preset.mode, r.best_preset.policy) {
            Some(t) if t == r.best_preset_total => Ok(()),
            other => Err(format!(
                "best preset {} re-prices to {other:?}, search said {}",
                r.best_preset.key(),
                r.best_preset_total
            )),
        }
    });
}

/// Satellite 1 (strictness): on a mixed-density map — one dense rim,
/// sparse elsewhere — the tuner must *strictly* beat at least one
/// preset (a uniform plan cannot be optimal everywhere at once).
#[test]
fn tuned_strictly_beats_a_preset_on_mixed_density_map() {
    let hw = Platform::EyerissLargeTile.hardware();
    let layer = ConvLayer::new(1, 1, 40, 40, 32, 32);
    let mut fm = generate(40, 40, 32, SparsityParams::clustered(0.08, 3));
    let dense = generate(40, 40, 32, SparsityParams::clustered(0.9, 4));
    for y in 0..40 {
        for x in 0..6 {
            for ch in 0..32 {
                fm.set(y, x, ch, dense.get(y, x, ch));
            }
        }
    }
    let mut tuner = Tuner::new(hw);
    let r = tuner.tune_layer(&layer, &fm);
    let tuned = r.total_bits();
    let mut beaten = 0usize;
    for (mode, preset) in candidate_modes(&layer) {
        if !preset {
            continue;
        }
        for policy in candidate_policies() {
            if let Some(t) = packed_total(&hw, &layer, &fm, mode, policy) {
                assert!(tuned <= t, "tuned worse than {} {}", mode.name(), policy.name());
                if tuned < t {
                    beaten += 1;
                }
            }
        }
    }
    assert!(beaten >= 1, "tuned plan ties every preset on a mixed-density map");
}

/// Satellite 1 (exactness): brute-force enumeration of the *entire*
/// candidate space — presets and anchored split-point probes alike —
/// through the independent pack-then-price path. The pruned search must
/// land on exactly the brute-force minimum: the lower bound is
/// admissible, so pruning never discards the optimum.
#[test]
fn search_matches_brute_force_enumeration() {
    let hw = Platform::NvidiaSmallTile.hardware();
    let cases = [
        (ConvLayer::new(1, 1, 16, 16, 8, 8), 0.30, 21u64),
        (ConvLayer::new(1, 2, 18, 14, 16, 16), 0.55, 22),
        (ConvLayer::new(2, 1, 20, 20, 8, 8), 0.15, 23),
    ];
    for (layer, density, seed) in cases {
        let fm = generate(layer.h, layer.w, layer.c_in, SparsityParams::clustered(density, seed));
        let mut tuner = Tuner::new(hw);
        let r = tuner.tune_layer(&layer, &fm);
        let mut brute = u64::MAX;
        let mut space = 0usize;
        for (mode, _) in candidate_modes(&layer) {
            for policy in candidate_policies() {
                if let Some(t) = packed_total(&hw, &layer, &fm, mode, policy) {
                    brute = brute.min(t);
                    space += 1;
                }
            }
        }
        assert!(space > 0, "empty plan space for {layer:?}");
        assert_eq!(
            r.total_bits(),
            brute,
            "search ({}, {} nodes, {} pruned) != brute force over {space} plans for {layer:?}",
            r.plan.key(),
            r.nodes,
            r.pruned
        );
    }
}

/// Satellite 2: the tuned manifest and study table are byte-identical
/// across `--jobs` ∈ {1, 2, 8} (the only parallelism under the search
/// is the packer's position-indexed sizing fan-out) and across repeated
/// runs with fresh tuners.
#[test]
fn tuned_manifest_identical_across_jobs_and_runs() {
    let mut renders: Vec<(usize, String, String)> = Vec::new();
    for jobs in [1usize, 2, 8] {
        set_threads(jobs);
        let (t, m) = tune_study(&[Network::AlexNet]);
        renders.push((jobs, t.render_csv(), m.render()));
    }
    set_threads(0);
    for (jobs, table, manifest) in &renders[1..] {
        assert_eq!(
            manifest, &renders[0].2,
            "tuned manifest bytes diverge between --jobs 1 and --jobs {jobs}"
        );
        assert_eq!(
            table, &renders[0].1,
            "tune table bytes diverge between --jobs 1 and --jobs {jobs}"
        );
    }
    let (t2, m2) = tune_study(&[Network::AlexNet]);
    assert_eq!(m2.render(), renders[0].2, "manifest bytes diverge across repeated runs");
    assert_eq!(t2.render_csv(), renders[0].1, "table bytes diverge across repeated runs");
}

/// Satellite 2: in a network with repeated layer specs the memo path
/// serves results bit-identical to the cold path — same plan, same
/// priced cost, same rendered manifest line (names aside) — and the
/// manifest round-trips through its text form.
#[test]
fn memo_path_is_bit_identical_in_repeated_layer_network() {
    let hw = Platform::NvidiaSmallTile.hardware();
    let mut tuner = Tuner::new(hw);
    let layer = ConvLayer::new(1, 1, 24, 24, 16, 16);
    let fm = generate(24, 24, 16, SparsityParams::clustered(0.3, 11));
    let other = ConvLayer::new(1, 1, 20, 20, 8, 8);
    let other_fm = generate(20, 20, 8, SparsityParams::clustered(0.5, 12));
    let layers = vec![
        ("a.conv1".to_string(), layer, fm.clone()),
        ("b.conv1".to_string(), other, other_fm),
        ("b.conv2".to_string(), layer, fm.clone()),
        ("c.conv1".to_string(), layer, fm),
    ];
    let (m, results) = tuner.tune_network(&layers);
    assert!(!results[0].memo_hit && !results[1].memo_hit);
    assert!(results[2].memo_hit && results[3].memo_hit);
    assert_eq!(tuner.memo_hits, 2);
    for hit in [&results[2], &results[3]] {
        assert_eq!(hit.plan, results[0].plan);
        assert_eq!(hit.cost, results[0].cost);
        assert_eq!(hit.nodes, 0, "memo hits price no nodes");
    }
    // Rendered manifest lines for the repeated spec differ only by name.
    let lines: Vec<Vec<&str>> = m
        .render()
        .lines()
        .filter(|l| l.starts_with("tuned "))
        .map(|l| l.split_whitespace().collect())
        .collect();
    assert_eq!(lines.len(), 4);
    for li in [2usize, 3] {
        assert_eq!(&lines[li][2..], &lines[0][2..], "memo line {li} diverges beyond the name");
    }
    let parsed = TunedManifest::parse(&m.render()).unwrap();
    assert_eq!(parsed, m);
    assert_eq!(parsed.get("b.conv2"), parsed.get("a.conv1"));
}

/// Satellite 3: pricer-seam backfill. Every extended division the tuner
/// can emit — anchored rims split at 1 and at edge−1, degenerate
/// single-block geometries, WholeMap, the compact baseline — prices
/// bit-exactly against the naive per-sub-tensor walker oracle, under
/// both a fixed codec and the adaptive policy.
#[test]
fn extended_divisions_price_exactly_like_the_naive_oracle() {
    let hw = Platform::NvidiaSmallTile.hardware();
    let geoms = [
        ConvLayer::new(1, 1, 24, 24, 16, 16),
        ConvLayer::new(2, 1, 17, 13, 8, 8), // ragged + halo
        ConvLayer::new(1, 2, 9, 9, 8, 8),   // degenerate: ~one block
        ConvLayer::new(1, 1, 6, 6, 8, 8),   // smaller than one 8-edge
    ];
    let modes = [
        DivisionMode::Anchored { edge: 8, anchor: 1 },
        DivisionMode::Anchored { edge: 8, anchor: 7 },
        DivisionMode::Anchored { edge: 4, anchor: 1 },
        DivisionMode::Anchored { edge: 2, anchor: 1 },
        DivisionMode::WholeMap,
        DivisionMode::Uniform { edge: 1 },
    ];
    let mut checked = 0usize;
    for layer in &geoms {
        let fm =
            generate(layer.h, layer.w, layer.c_in, SparsityParams::clustered(0.35, 77));
        for mode in modes {
            for policy in [CodecPolicy::Fixed(Scheme::Zrlc), CodecPolicy::Adaptive] {
                let (Ok(fast), Ok(naive)) = (
                    run_layer(&hw, layer, &fm, mode, policy),
                    run_layer_naive(&hw, layer, &fm, mode, policy),
                ) else {
                    continue;
                };
                assert_eq!(fast.fetched_bits, naive.fetched_bits, "{} fetch", mode.name());
                assert_eq!(fast.metadata_bits, naive.metadata_bits, "{} meta", mode.name());
                assert_eq!(fast.baseline_bits, naive.baseline_bits, "{} base", mode.name());
                checked += 1;
            }
        }
    }
    assert!(checked >= 30, "only {checked} (geometry, mode, policy) seams existed");
}

/// Satellite 3: record/tag-bit accounting under tuned mixed plans. For
/// one division, metadata traffic is `record_bits × touched-records`;
/// the touch count is policy-independent, so the adaptive and fixed
/// totals must be exact multiples of their per-record widths with equal
/// quotients, and the widths must differ by exactly
/// `TAG_BITS × record_slots` (the Fig. 7 per-slot codec tags).
#[test]
fn adaptive_tag_bits_match_record_slot_accounting() {
    let hw = Platform::EyerissLargeTile.hardware();
    for mode in [
        DivisionMode::GrateTile { n: 8 },
        DivisionMode::Anchored { edge: 8, anchor: 1 },
        DivisionMode::Anchored { edge: 4, anchor: 3 },
    ] {
        let layer = ConvLayer::new(1, 1, 33, 29, 16, 16);
        let tile = hw.tile_for_layer(&layer);
        let division =
            Division::build(mode, &layer, &tile, &hw, layer.h, layer.w, layer.c_in).unwrap();
        let rb_fixed = record_bits_for(&division, CodecPolicy::Fixed(Scheme::Bitmask)) as u64;
        let rb_auto = record_bits_for(&division, CodecPolicy::Adaptive) as u64;
        assert_eq!(
            rb_auto - rb_fixed,
            (TAG_BITS * division.record_slots()) as u64,
            "{}: adaptive record width must add one tag per slot",
            mode.name()
        );
        let fm = generate(33, 29, 16, SparsityParams::clustered(0.4, 41));
        let fixed = run_layer(&hw, &layer, &fm, mode, Scheme::Bitmask).unwrap();
        let auto = run_layer(&hw, &layer, &fm, mode, CodecPolicy::Adaptive).unwrap();
        assert_eq!(fixed.metadata_bits % rb_fixed, 0, "{}", mode.name());
        assert_eq!(auto.metadata_bits % rb_auto, 0, "{}", mode.name());
        assert_eq!(
            fixed.metadata_bits / rb_fixed,
            auto.metadata_bits / rb_auto,
            "{}: record touch count must be policy-independent",
            mode.name()
        );
        assert!(auto.metadata_bits > fixed.metadata_bits, "{}", mode.name());
    }
}
