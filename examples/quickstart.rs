//! Quickstart: divide, pack, fetch and price one sparse feature map.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use gratetile::compress::Scheme;
use gratetile::config::hardware::Platform;
use gratetile::config::layer::ConvLayer;
use gratetile::layout::{Fetcher, Packer};
use gratetile::memsim::{Dram, Stream};
use gratetile::sim::experiment::run_layer;
use gratetile::tensor::sparsity::{generate, SparsityParams};
use gratetile::tiling::{Division, DivisionMode};

fn main() -> gratetile::util::error::Result<()> {
    // A VGG-ish layer: 3x3 stride-1 conv over a 56x56x64 input map at
    // 35% density (typical mid-network ReLU sparsity).
    let hw = Platform::EyerissLargeTile.hardware();
    let layer = ConvLayer::new(1, 1, 56, 56, 64, 64);
    let fm = generate(56, 56, 64, SparsityParams::clustered(0.35, 42));
    println!("feature map: {}x{}x{} density {:.1}%", fm.h, fm.w, fm.c, fm.density() * 100.0);

    // 1. The GrateTile configuration (Eq. 1) and division.
    let tile = hw.tile_for_layer(&layer);
    let mode = DivisionMode::GrateTile { n: 8 };
    let division = Division::build(mode, &layer, &tile, &hw, fm.h, fm.w, fm.c)?;
    println!(
        "division: {} -> {} sub-tensors, {} metadata blocks ({} bits each)",
        mode.name(),
        division.n_subtensors(),
        division.n_blocks(),
        division.meta_bits_per_block,
    );

    // 2. Pack: compress every sub-tensor, assign aligned addresses.
    let packed = Packer::new(hw, Scheme::Bitmask).pack(&fm, &division, true);
    println!(
        "packed: {} -> {} words ({:.1}% of dense), metadata {} bits total",
        fm.words(),
        packed.total_words,
        packed.compression_ratio() * 100.0,
        packed.metadata.total_bits(),
    );

    // 3. Fetch one processing window on-the-fly (decompressing), with
    //    DRAM traffic accounted.
    let mut dram = Dram::default();
    let mut fetcher = Fetcher::new(&packed);
    let win = fetcher.fetch_window(&mut dram, 15, 33, 15, 33, 0, 16);
    println!(
        "fetched window [15,33)x[15,33)x[0,16): {} feature lines + {} metadata words; sample value {:.3}",
        dram.lines_of(Stream::FeatureRead),
        dram.words_of(Stream::MetadataRead),
        win.get(20, 20, 3),
    );

    // 4. Price the full layer against the uncompressed baseline.
    let report = run_layer(&hw, &layer, &fm, mode, Scheme::Bitmask)?;
    println!(
        "layer bandwidth: saved {:.1}% (w/ metadata; optimal {:.1}%) over {} tiles",
        report.saving_with_meta() * 100.0,
        report.optimal_saving() * 100.0,
        report.n_tiles,
    );
    Ok(())
}
