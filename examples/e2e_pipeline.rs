//! End-to-end driver (the full-system validation of EXPERIMENTS.md §E2E):
//!
//! 1. Load the AOT-compiled JAX/Pallas CNN via PJRT (`make artifacts`).
//! 2. Run it on structured synthetic images → *real* ReLU activations.
//! 3. Store every activation map in GrateTile format (divide → compress
//!    → aligned layout + Fig. 7 metadata).
//! 4. Drive the double-buffered coordinator pipeline over the packed
//!    maps (fetch → decompress → convolve → ReLU → repack), verifying
//!    outputs against a dense reference.
//! 5. Report per-layer bandwidth savings vs. the uncompressed baseline
//!    and pipeline throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use gratetile::compress::Scheme;
use gratetile::config::hardware::Platform;
use gratetile::config::layer::ConvLayer;
use gratetile::coordinator::{direct_conv_relu, LayerRunner, PipelineConfig, Weights};
use gratetile::runtime::{Engine, Manifest};
use gratetile::sim::experiment::run_layer;
use gratetile::tiling::DivisionMode;
use gratetile::util::table::Table;
use std::path::Path;
use std::time::Instant;

fn main() -> gratetile::util::error::Result<()> {
    let artifacts = Path::new("artifacts");
    let manifest = Manifest::load(artifacts)?;
    let entry = manifest.get("cnn")?;
    let engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());
    let model = engine.load_entry(entry)?;
    println!("compiled {} ({} layers)", entry.file.display(), entry.n_outputs);

    let (h, w, c) = (entry.input_dims[0], entry.input_dims[1], entry.input_dims[2]);
    let mut cfg = PipelineConfig::new(Platform::NvidiaSmallTile.hardware());
    cfg.mode = DivisionMode::GrateTile { n: 8 };
    cfg.policy = Scheme::Bitmask.into();
    let runner = LayerRunner::new(cfg);

    let mut t = Table::new("E2E — JAX/Pallas CNN activations through the GrateTile pipeline")
        .header(vec![
            "img", "layer", "density %", "saved % (grate8)", "saved % (uniform8)",
            "tiles/s", "verified",
        ]);
    let n_images = 4;
    let start = Instant::now();
    let mut total_tiles = 0u64;

    for img_i in 0..n_images {
        // Structured image: gradient + oriented waves, per-image phase.
        let image: Vec<f32> = (0..h * w * c)
            .map(|i| {
                let y = (i / (w * c)) as f32 / h as f32;
                let x = ((i / c) % w) as f32 / w as f32;
                let p = img_i as f32 * 0.7;
                (x * y + (7.0 * x + p).sin() * 0.15 + (5.0 * y - p).cos() * 0.1).max(0.0)
            })
            .collect();

        // Real activations from the AOT CNN (Python never runs here).
        let fms = model.run_cnn(entry, &image)?;

        for (li, fm) in fms.iter().enumerate() {
            let layer = ConvLayer::new(1, 1, fm.h, fm.w, fm.c, fm.c);
            let grate = run_layer(&cfg.hw, &layer, fm, DivisionMode::GrateTile { n: 8 }, cfg.policy)?;
            let uni = run_layer(&cfg.hw, &layer, fm, DivisionMode::Uniform { edge: 8 }, cfg.policy)?;

            // Run the actual pipeline and verify against the dense oracle.
            let weights = Weights::random(&layer, 100 + li as u64);
            let packed = runner.pack(&layer, fm)?;
            let (out, m) = runner.run_layer(&layer, &weights, &packed)?;
            let oracle = direct_conv_relu(&layer, &weights, fm);
            let max_rel = out
                .as_slice()
                .iter()
                .zip(oracle.as_slice())
                .map(|(&a, &b)| (a - b).abs() / a.abs().max(b.abs()).max(1.0))
                .fold(0.0f32, f32::max);
            total_tiles += m.tiles;

            t.row(vec![
                format!("{img_i}"),
                format!("L{li} {}x{}x{}", fm.h, fm.w, fm.c),
                format!("{:.1}", fm.density() * 100.0),
                format!("{:.1}", grate.saving_with_meta() * 100.0),
                format!("{:.1}", uni.saving_with_meta() * 100.0),
                format!("{:.0}", m.tiles_per_sec()),
                if max_rel < 0.02 { "ok".into() } else { format!("FAIL {max_rel}") },
            ]);
        }
    }

    println!("{}", t.render());
    t.save_csv("e2e_pipeline");
    println!(
        "processed {n_images} images x {} layers = {} tiles in {:.2}s",
        entry.n_outputs,
        total_tiles,
        start.elapsed().as_secs_f64()
    );
    Ok(())
}
