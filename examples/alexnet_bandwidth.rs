//! AlexNet bandwidth study: the Fig. 9 per-layer rows for one network,
//! every division mode, both platforms.
//!
//! ```bash
//! cargo run --release --example alexnet_bandwidth
//! ```

use gratetile::compress::Scheme;
use gratetile::config::hardware::Platform;
use gratetile::config::zoo::{network_layers, Network};
use gratetile::sim::experiment::{bench_feature_map, run_bench_layer};
use gratetile::tiling::DivisionMode;
use gratetile::util::table::Table;

fn main() {
    for platform in [Platform::NvidiaSmallTile, Platform::EyerissLargeTile] {
        let hw = platform.hardware();
        let modes = DivisionMode::table3_modes();
        let mut header = vec!["Layer".to_string(), "Optimal %".to_string()];
        header.extend(modes.iter().map(|m| m.name()));
        let mut t = Table::new(&format!(
            "AlexNet bandwidth savings, {} (bitmask, with metadata)",
            hw.name
        ))
        .header(header);
        for bench in network_layers(Network::AlexNet) {
            let fm = bench_feature_map(&bench);
            let mut row =
                vec![bench.name.to_string(), format!("{:.1}", (1.0 - fm.density()) * 100.0)];
            for &mode in &modes {
                row.push(
                    run_bench_layer(&hw, &bench, mode, Scheme::Bitmask, &fm)
                        .map(|r| format!("{:.1}", r.saving_with_meta() * 100.0))
                        .unwrap_or_else(|_| "N/A".into()),
                );
            }
            t.row(row);
        }
        println!("{}", t.render());
    }
}
