//! Serving demo: a leader admitting inference requests to worker
//! pipelines that keep all intermediate activations in GrateTile
//! storage. Reports throughput and latency percentiles.
//!
//! ```bash
//! cargo run --release --example serve -- 4 32   # workers, requests
//! ```

use gratetile::config::hardware::Platform;
use gratetile::config::layer::ConvLayer;
use gratetile::coordinator::{PipelineConfig, Server, ServerConfig, Weights};

fn main() -> gratetile::util::error::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let workers: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(32);

    // A small VDSR-flavoured stack.
    let l1 = ConvLayer::new(1, 1, 32, 32, 8, 16);
    let l2 = ConvLayer::new(1, 1, 32, 32, 16, 16);
    let l3 = ConvLayer::new(1, 2, 32, 32, 16, 16);
    let l4 = ConvLayer::new(1, 1, 16, 16, 16, 8);
    let layers = vec![
        (l1, Weights::random(&l1, 1)),
        (l2, Weights::random(&l2, 2)),
        (l3, Weights::random(&l3, 3)),
        (l4, Weights::random(&l4, 4)),
    ];

    let server = Server::new(
        ServerConfig {
            pipeline: PipelineConfig::new(Platform::NvidiaSmallTile.hardware()),
            workers,
            queue_depth: workers * 2,
        },
        layers,
    );
    println!("serving {requests} requests on {workers} workers ...");
    let report = server.serve(server.synthetic_requests(requests, 0.5, 13))?;
    println!("{}", report.summary());
    Ok(())
}
